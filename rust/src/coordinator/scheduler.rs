//! Continuous-batching scheduler: chunk-granular prefill interleaved with a
//! batched decode stream (the full request lifecycle, vLLM-style), driven
//! entirely through `dyn ExecBackend`.
//!
//! Requests move through the typed [`RunState`] lifecycle: *prefilling*
//! (chunk-granular), *decoding* (one token per round, new K/V appended to
//! the same paged reservation), and *finished* (KV freed, final response
//! sent).  Every scheduling round (1) admits new work — resolving the
//! request's bucket, clamping `max_new_tokens` to the coordinator cap (and
//! to zero for backends without the decode capability), rejecting
//! never-fit requests at admission, and — for backends with the `chunked`
//! capability, the only ones that touch the paged store — reserving
//! `bucket + max_new` rows in the paged KV store all-or-nothing so an
//! admitted request can always prefill *and* decode to completion.
//! With the prefix cache on, the reservation first probes the store's
//! shared-prefix index with the backend's content chain
//! ([`ExecBackend::prefix_chain`]): already-resident leading prompt
//! blocks are pinned (shared) instead of re-reserved, the hit rides into
//! [`ExecBackend::begin`] so the backend resumes past the cached rows,
//! and `prefix_hits` / `prefix_blocks_shared` / `prefix_evictions` land
//! in the metrics;
//! (2) dispatches the next chunk of
//! every prefilling request — across the worker pool when the backend's
//! [`Capabilities`] allow sharing, serially otherwise; and (3) runs one
//! batched decode step across all decoding requests.  Decode streams
//! therefore keep producing tokens while a 128k prefill is mid-sequence —
//! neither direction can starve the other, because both get exactly one
//! round of service per loop iteration.
//!
//! The scheduler never inspects which backend it is running: everything it
//! needs to know (chunked? parallel? decode? largest bucket?) comes from
//! [`Capabilities`], and the prefill -> decode transition is the backend's
//! call ([`ChunkStep::EnterDecode`]) — there is no capability probing or
//! feature-gated dispatch here.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};

use crate::util::rng::Rng;

use super::admission::{AdmissionQueue, WorkItem};
use super::backend::{Capabilities, ChunkStep, DecodeStep, ExecBackend, RunState};
use super::kv_cache::PagedKvStore;
use super::metrics::Metrics;
use super::request::{PrefillResponse, ResponseEvent};

/// Scheduler knobs (from `CoordinatorConfig`).
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Default rows per prefill chunk (a request's `chunk` field overrides).
    pub chunk_tokens: usize,
    /// Requests admitted concurrently (prefilling + decoding) — the
    /// interleaving width and the decode batch-size ceiling.
    pub max_inflight: usize,
    /// How long to wait for work when idle.
    pub max_wait: std::time::Duration,
    /// Server-side cap on per-request `max_new_tokens` (requests asking for
    /// more are clamped at admission).
    pub max_new_cap: usize,
    /// Probe the paged store's shared-prefix index at admission and pin
    /// already-resident prompt blocks into new reservations (chunked
    /// backends only).
    pub prefix_cache: bool,
}

/// One prefilling request: its run state plus the reply channel.
struct Inflight {
    run: RunState,
    reply: mpsc::Sender<ResponseEvent>,
}

/// The decode batch: runs and reply channels, index-aligned (the backend's
/// `decode_step` takes a bare `&mut [RunState]`).
#[derive(Default)]
struct DecodeLane {
    runs: Vec<RunState>,
    replies: Vec<mpsc::Sender<ResponseEvent>>,
}

impl DecodeLane {
    fn len(&self) -> usize {
        self.runs.len()
    }

    fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    fn push(&mut self, run: RunState, reply: mpsc::Sender<ResponseEvent>) {
        self.runs.push(run);
        self.replies.push(reply);
    }
}

/// The scheduler loop: runs on the coordinator's executor thread until
/// `stop` is set and all queues drain.
pub(crate) fn run_loop(
    cfg: &SchedulerConfig,
    backend: &dyn ExecBackend,
    adm: &AdmissionQueue,
    store: &PagedKvStore,
    met: &Metrics,
    stop: &AtomicBool,
    rng: &mut Rng,
) {
    let caps = backend.capabilities();
    // `max_bucket` is the second copy of what `buckets()` already says;
    // enforce the single-source invariant once, loudly, so an out-of-tree
    // backend cannot ship an inconsistent pair (the admission error message
    // cites `max_bucket`, the admission decision uses `bucket_for`).
    assert_eq!(
        Some(caps.max_bucket),
        backend.buckets().iter().copied().max(),
        "backend '{}' reports max_bucket inconsistent with its bucket list",
        backend.name()
    );
    let mut ready: VecDeque<Inflight> = VecDeque::new();
    let mut decoding = DecodeLane::default();
    loop {
        if stop.load(Ordering::Relaxed) && adm.is_empty() && ready.is_empty() && decoding.is_empty()
        {
            break;
        }
        admit(cfg, backend, &caps, adm, store, met, &mut ready, decoding.len(), rng);
        if ready.is_empty() && decoding.is_empty() {
            if stop.load(Ordering::Relaxed) && adm.is_empty() {
                break;
            }
            continue; // `admit` already waited up to max_wait
        }
        // One prefill chunk per prefilling request...
        if !ready.is_empty() {
            dispatch_round(cfg, backend, &caps, store, met, &mut ready, &mut decoding);
        }
        // ...and one batched decode step across all decoding requests, every
        // round — decode streams flow while long prefills are mid-sequence.
        if !decoding.is_empty() {
            decode_round(backend, store, met, &mut decoding);
        }
    }
}

/// Pull new requests out of admission into the ready ring.  Over-cap
/// requests are rejected here — at admission, with a clear error — instead
/// of failing deep in the backend; requests the KV pool cannot hold yet are
/// requeued (backpressure) and admission pauses until blocks free up.
#[allow(clippy::too_many_arguments)]
fn admit(
    cfg: &SchedulerConfig,
    backend: &dyn ExecBackend,
    caps: &Capabilities,
    adm: &AdmissionQueue,
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
    decoding: usize,
    rng: &mut Rng,
) {
    // `max_inflight` bounds admitted requests across both lifecycle phases
    // (each holds a full `bucket + max_new` KV reservation): a full system
    // admits nothing until something completes.
    let want = cfg.max_inflight.saturating_sub(ready.len() + decoding);
    if want == 0 {
        return;
    }
    // Only block waiting for work when there is nothing at all to schedule.
    let wait =
        if ready.is_empty() && decoding == 0 { cfg.max_wait } else { std::time::Duration::ZERO };
    let mut pending: VecDeque<WorkItem> = adm.pop_up_to(want, wait).into();
    while let Some(mut item) = pending.pop_front() {
        let n = item.req.seq_len();
        let Some(bucket) = backend.bucket_for(n) else {
            reject(
                met,
                &item,
                format!(
                    "rejected at admission: seq_len {n} exceeds largest bucket {}",
                    caps.max_bucket
                ),
            );
            continue;
        };
        // Decode rows live in the same reservation as the prompt, so the
        // clamped token budget is part of the admission footprint.
        item.req.max_new_tokens = item.req.max_new_tokens.min(cfg.max_new_cap);
        if !caps.decode {
            // Backends without the decode capability complete at prefill:
            // don't reserve — or reject for — decode rows that can never be
            // used.
            item.req.max_new_tokens = 0;
        }
        // Only chunked backends touch the paged store: reserving rows for a
        // backend that executes monolithically would strand pool capacity
        // on pure accounting (and spuriously reject on small pools).
        let mut prefix: Option<super::backend::PrefixHit> = None;
        if caps.chunked {
            let rows = bucket + item.req.max_new_tokens;
            if rows > store.total_blocks * store.block_size {
                // Can NEVER fit, even with the pool idle: requeueing would
                // spin forever and head-of-line-block everything behind it.
                reject(
                    met,
                    &item,
                    format!(
                        "rejected at admission: bucket {bucket} + {} new tokens exceeds kv pool capacity ({} blocks x {} rows)",
                        item.req.max_new_tokens, store.total_blocks, store.block_size
                    ),
                );
                continue;
            }
            // Prefix-cache admission: probe the store's index with the
            // request's content chain; matching leading blocks are pinned
            // (shared) into the reservation and only the tail is fresh.
            let chain = if cfg.prefix_cache {
                backend.prefix_chain(&item.req, bucket, store.block_size)
            } else {
                None
            };
            let outcome = store.reserve_with_prefix(item.req.id, rows, chain.as_ref());
            met.prefix_evictions.fetch_add(outcome.evicted as u64, Ordering::Relaxed);
            if !outcome.reserved {
                met.kv_rejections.fetch_add(1, Ordering::Relaxed);
                // Pool is full right now: put this item and everything
                // popped behind it back at the FRONT of admission in
                // arrival order, and retry after in-flight work frees
                // blocks.
                pending.push_front(item);
                while let Some(it) = pending.pop_back() {
                    adm.requeue(it);
                }
                break;
            }
            if outcome.hit_rows > 0 {
                met.prefix_hits.fetch_add(1, Ordering::Relaxed);
                met.prefix_blocks_shared.fetch_add(outcome.hit_blocks as u64, Ordering::Relaxed);
            }
            prefix = chain.map(|chain| super::backend::PrefixHit {
                chain,
                rows: outcome.hit_rows,
                aux: outcome.aux,
            });
        }
        let run = backend.begin(item.req, bucket, cfg.chunk_tokens, prefix, rng);
        ready.push_back(Inflight { run, reply: item.reply });
    }
}

/// Fail a request at admission with a clear error.
fn reject(met: &Metrics, item: &WorkItem, msg: String) {
    let resp = PrefillResponse { id: item.req.id, error: Some(msg), ..Default::default() };
    met.record(&resp);
    let _ = item.reply.send(ResponseEvent::Done(resp));
}

/// Dispatch one chunk for up to `max_inflight` ready requests.  Backends
/// with the `parallel` capability fan the chunks across the worker pool
/// (each worker runs its chunk's kernels serially — the pool pins nested
/// parallelism to 1); others process the round serially on this thread.
/// Unfinished runs rejoin the BACK of the ready ring, which is what makes
/// scheduling round-robin; runs the backend transitioned into the decode
/// phase ([`ChunkStep::EnterDecode`]) move to the decode lane with their KV
/// reservation intact.
fn dispatch_round(
    cfg: &SchedulerConfig,
    backend: &dyn ExecBackend,
    caps: &Capabilities,
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
    decoding: &mut DecodeLane,
) {
    let take = ready.len().min(cfg.max_inflight.max(1));
    let round: Vec<Inflight> = ready.drain(..take).collect();
    let survivors: Mutex<Vec<Inflight>> = Mutex::new(Vec::with_capacity(take));
    let entering_decode: Mutex<Vec<Inflight>> = Mutex::new(Vec::new());
    let step = |mut job: Inflight, b: &dyn ExecBackend| match b.prefill_chunk(&mut job.run, store)
    {
        ChunkStep::Progress => survivors.lock().unwrap().push(job),
        ChunkStep::EnterDecode => entering_decode.lock().unwrap().push(job),
        ChunkStep::Done(resp) => {
            store.free(job.run.id());
            met.record(&resp);
            let _ = job.reply.send(ResponseEvent::Done(resp));
        }
    };
    if caps.parallel() && round.len() > 1 {
        // SAFETY of the Sync wrapper: taken only when the backend opted
        // into parallel dispatch through the *unsafe*
        // `Capabilities::with_parallel_dispatch`, whose contract is exactly
        // this — `&self` is soundly shareable across threads (plain owned
        // data, no interior mutability); `prefill_chunk` takes `&self`.
        struct ShareBackend<'a>(&'a dyn ExecBackend);
        unsafe impl Sync for ShareBackend<'_> {}
        impl<'a> ShareBackend<'a> {
            // Method (not field access) so the closure captures the whole
            // Sync wrapper rather than the inner reference (2021 disjoint
            // capture).
            fn backend(&self) -> &'a dyn ExecBackend {
                self.0
            }
        }
        let b = ShareBackend(backend);
        crate::util::parallel::par_drain(round, |job| step(job, b.backend()));
    } else {
        for job in round {
            step(job, backend);
        }
    }
    // Survivors and decode entrants rejoin in request-id order for
    // determinism (par_drain completes in arbitrary order).
    let mut back = survivors.into_inner().unwrap();
    back.sort_by_key(|j| j.run.id());
    for job in back {
        ready.push_back(job);
    }
    let mut entrants = entering_decode.into_inner().unwrap();
    entrants.sort_by_key(|j| j.run.id());
    for Inflight { run, reply } in entrants {
        debug_assert!(run.is_decoding(), "EnterDecode must leave the run in the decode phase");
        decoding.push(run, reply);
    }
}

/// One batched decode step: every decoding request generates its next token
/// (the backend may fan the batch across the worker pool), frames stream
/// out as soon as they exist, and finished requests free their KV and
/// reply.  Early-stopped generations (stop token before `max_new_tokens`)
/// are counted separately; their unused KV tail was already reclaimed by
/// the backend.
fn decode_round(
    backend: &dyn ExecBackend,
    store: &PagedKvStore,
    met: &Metrics,
    decoding: &mut DecodeLane,
) {
    let steps = backend.decode_step(&mut decoding.runs, store);
    assert_eq!(
        steps.len(),
        decoding.runs.len(),
        "backend '{}' broke the decode_step contract: one index-aligned DecodeStep per run",
        backend.name()
    );
    let runs = std::mem::take(&mut decoding.runs);
    let replies = std::mem::take(&mut decoding.replies);
    for ((run, reply), step) in runs.into_iter().zip(replies).zip(steps) {
        match step {
            DecodeStep::Token(frame) => {
                let _ = reply.send(ResponseEvent::Token(frame));
                decoding.push(run, reply);
            }
            DecodeStep::Done(frame, resp) => {
                let _ = reply.send(ResponseEvent::Token(frame));
                if resp.tokens.len() < run.request().max_new_tokens {
                    met.early_stopped.fetch_add(1, Ordering::Relaxed);
                }
                store.free(run.id());
                met.record(&resp);
                let _ = reply.send(ResponseEvent::Done(resp));
            }
            DecodeStep::Failed(resp) => {
                store.free(run.id());
                met.record(&resp);
                let _ = reply.send(ResponseEvent::Done(resp));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::native::NativeBackend;
    use crate::coordinator::backend::reference::ReferenceBackend;
    use crate::coordinator::engine::EngineConfig;
    use crate::coordinator::{AttentionMode, PrefillRequest};

    fn setup() -> (SchedulerConfig, NativeBackend, AdmissionQueue, PagedKvStore, Metrics) {
        let ecfg = EngineConfig::default();
        let backend = NativeBackend::quick(ecfg.clone());
        let store = PagedKvStore::new(256, 64, ecfg.synth.head_dim);
        (
            SchedulerConfig {
                chunk_tokens: 128,
                max_inflight: 8,
                max_wait: std::time::Duration::from_millis(1),
                max_new_cap: 256,
                prefix_cache: true,
            },
            backend,
            AdmissionQueue::new(64),
            store,
            Metrics::new(),
        )
    }

    fn submit(adm: &AdmissionQueue, id: u64, n: usize) -> mpsc::Receiver<ResponseEvent> {
        submit_gen(adm, id, n, 0)
    }

    fn submit_gen(
        adm: &AdmissionQueue,
        id: u64,
        n: usize,
        max_new: usize,
    ) -> mpsc::Receiver<ResponseEvent> {
        let (tx, rx) = mpsc::channel();
        let mut req = PrefillRequest::synthetic(id, n, id, AttentionMode::Sparse);
        req.max_new_tokens = max_new;
        adm.push(WorkItem { req, reply: tx }).unwrap();
        rx
    }

    /// Drain a reply stream to its final response, counting token frames.
    fn final_of(rx: &mpsc::Receiver<ResponseEvent>) -> (usize, PrefillResponse) {
        let mut frames = 0;
        loop {
            match rx.recv().unwrap() {
                ResponseEvent::Token(_) => frames += 1,
                ResponseEvent::Done(resp) => return (frames, resp),
            }
        }
    }

    #[test]
    fn drains_all_work_then_stops() {
        let (cfg, backend, adm, store, met) = setup();
        let rxs: Vec<_> = (0..6).map(|i| submit(&adm, i, 128 + (i as usize % 2) * 128)).collect();
        let stop = AtomicBool::new(true); // pre-set: loop exits once drained
        let mut rng = Rng::new(1);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(final_of(&rx).1.ok);
        }
        assert_eq!(met.snapshot().completed, 6);
        assert_eq!(store.used(), 0, "all reservations freed");
    }

    #[test]
    fn serial_backend_drains_the_same_workload() {
        // The reference backend reports `parallel: false`, driving the
        // scheduler's serial dispatch path through the identical lifecycle.
        let (cfg, _backend, adm, store, met) = setup();
        let backend = ReferenceBackend::quick(EngineConfig::default());
        assert!(!backend.capabilities().parallel());
        let rxs: Vec<_> = (0..4).map(|i| submit(&adm, i, 128)).collect();
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(8);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(final_of(&rx).1.ok);
        }
        assert_eq!(met.snapshot().completed, 4);
        assert_eq!(store.used(), 0);
    }

    #[test]
    fn over_cap_rejected_at_admission() {
        let (cfg, backend, adm, store, met) = setup();
        let rx = submit(&adm, 1, 999_999);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(2);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, resp) = final_of(&rx);
        assert!(!resp.ok);
        let err = resp.error.unwrap();
        assert!(err.contains("rejected at admission"), "{err}");
        assert!(err.contains("exceeds largest bucket"), "{err}");
        assert_eq!(met.snapshot().failed, 1);
        assert_eq!(store.used(), 0);
    }

    #[test]
    fn never_fit_bucket_rejected_not_requeued() {
        let (cfg, backend, adm, big_store, met) = setup();
        // Pool (4 x 64 = 256 rows) smaller than the 512 bucket: the request
        // must be rejected at admission, not requeued forever, and must not
        // block the servable request behind it.
        let store = PagedKvStore::new(4, 64, big_store.head_dim);
        let bad_rx = submit(&adm, 1, 512);
        let ok_rx = submit(&adm, 2, 128);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(4);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, bad) = final_of(&bad_rx);
        assert!(!bad.ok);
        assert!(bad.error.unwrap().contains("exceeds kv pool capacity"));
        assert!(final_of(&ok_rx).1.ok);
        assert_eq!(met.snapshot().completed, 1);
        assert_eq!(met.snapshot().failed, 1);
    }

    #[test]
    fn decode_footprint_counts_against_pool_capacity() {
        let (cfg, backend, adm, big_store, met) = setup();
        // Pool of exactly 256 rows: a 256-row prompt fits alone, but the
        // same prompt + 10 decode tokens can never fit and must be rejected
        // at admission (the reservation covers prompt + max_new).
        let store = PagedKvStore::new(4, 64, big_store.head_dim);
        let bad_rx = submit_gen(&adm, 1, 256, 10);
        let ok_rx = submit_gen(&adm, 2, 256, 0);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(5);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, bad) = final_of(&bad_rx);
        assert!(!bad.ok);
        assert!(bad.error.unwrap().contains("new tokens exceeds kv pool capacity"));
        assert!(final_of(&ok_rx).1.ok);
    }

    #[test]
    fn kv_exhaustion_requeues_and_recovers() {
        let (cfg, backend, adm, big_store, met) = setup();
        // Pool that fits exactly one 1024-bucket request at a time.
        let store = PagedKvStore::new(16, 64, big_store.head_dim);
        let rxs: Vec<_> = (0..3).map(|i| submit(&adm, i, 1024)).collect();
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(3);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(final_of(&rx).1.ok, "requeued requests complete eventually");
        }
        let snap = met.snapshot();
        assert_eq!(snap.completed, 3);
        assert!(snap.kv_rejections > 0, "backpressure must have engaged");
    }

    #[test]
    fn generation_streams_frames_then_final_response() {
        let (cfg, backend, adm, store, met) = setup();
        let rx = submit_gen(&adm, 1, 128, 5);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(6);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (frames, resp) = final_of(&rx);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(frames, 5, "one streamed frame per generated token");
        assert_eq!(resp.tokens.len(), 5);
        assert_eq!(resp.decode_us.len(), 5);
        assert_eq!(store.used(), 0, "prompt + decode reservation freed");
        let snap = met.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.tokens_generated, 5);
        assert_eq!(snap.early_stopped, 0);
    }

    #[test]
    fn max_new_tokens_clamped_to_cap() {
        let (mut cfg, backend, adm, store, met) = setup();
        cfg.max_new_cap = 3;
        let rx = submit_gen(&adm, 1, 128, 100);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(7);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (frames, resp) = final_of(&rx);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 3, "clamped to max_new_cap");
        assert_eq!(frames, 3);
    }

    #[test]
    fn repeated_prefix_skips_prefill_and_counts_hits() {
        let (cfg, backend, adm, store, met) = setup();
        // Cold request: same seed replayed later under a different id.
        let cold_rx = {
            let (tx, rx) = mpsc::channel();
            let req = PrefillRequest::synthetic(1, 256, 77, AttentionMode::Sparse);
            adm.push(WorkItem { req, reply: tx }).unwrap();
            rx
        };
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(10);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, cold) = final_of(&cold_rx);
        assert!(cold.ok, "{:?}", cold.error);
        assert_eq!(cold.chunks, 2, "256 rows at chunk 128");
        assert_eq!(cold.cached_rows, 0);
        assert_eq!(store.used(), 0, "cached blocks are idle capacity, not usage");
        assert!(store.cached_idle() > 0, "completed prompt stays resident");

        let warm_rx = {
            let (tx, rx) = mpsc::channel();
            let req = PrefillRequest::synthetic(2, 256, 77, AttentionMode::Sparse);
            adm.push(WorkItem { req, reply: tx }).unwrap();
            rx
        };
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, warm) = final_of(&warm_rx);
        assert!(warm.ok, "{:?}", warm.error);
        assert_eq!(warm.cached_rows, 256, "whole prompt served from the cache");
        assert_eq!(warm.chunks, 1, "one bookkeeping round instead of two compute chunks");
        assert_eq!(warm.output_digest, cold.output_digest, "digest identical to the cold run");
        assert_eq!(warm.density, cold.density, "density identical to the cold run");
        let snap = met.snapshot();
        assert_eq!(snap.prefix_hits, 1);
        assert_eq!(snap.prefix_blocks_shared, 4, "256 rows at 64-row blocks");
        store.assert_consistent();

        // A different prompt shares nothing.
        let other_rx = submit(&adm, 3, 256);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, other) = final_of(&other_rx);
        assert!(other.ok);
        assert_eq!(other.cached_rows, 0);
        assert_eq!(met.snapshot().prefix_hits, 1, "no spurious hits");
    }

    #[test]
    fn prefix_cache_off_means_no_sharing() {
        let (mut cfg, backend, adm, store, met) = setup();
        cfg.prefix_cache = false;
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(12);
        for id in [1u64, 2] {
            let (tx, rx) = mpsc::channel();
            let req = PrefillRequest::synthetic(id, 256, 99, AttentionMode::Sparse);
            adm.push(WorkItem { req, reply: tx }).unwrap();
            run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
            let (_, resp) = final_of(&rx);
            assert!(resp.ok);
            assert_eq!(resp.cached_rows, 0);
            assert_eq!(resp.chunks, 2, "full prefill both times");
        }
        let snap = met.snapshot();
        assert_eq!(snap.prefix_hits, 0);
        assert_eq!(store.cached_idle(), 0, "nothing published with the cache off");
    }

    #[test]
    fn stop_token_ends_generation_early_and_reclaims_kv() {
        let (cfg, backend, adm, store, met) = setup();
        // Learn the deterministic token stream first, then replay the same
        // request with its second token as the stop token.
        let probe_rx = submit_gen(&adm, 1, 128, 6);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(9);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, probe) = final_of(&probe_rx);
        assert!(probe.ok, "{:?}", probe.error);
        assert_eq!(probe.tokens.len(), 6);

        let (tx, rx) = mpsc::channel();
        let mut req = PrefillRequest::synthetic(2, 128, 1, AttentionMode::Sparse);
        req.max_new_tokens = 6;
        req.stop_token = Some(probe.tokens[1]);
        adm.push(WorkItem { req, reply: tx }).unwrap();
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (frames, resp) = final_of(&rx);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 2, "generation stops at the stop token");
        assert_eq!(resp.tokens, probe.tokens[..2], "stop token itself is emitted");
        assert_eq!(frames, 2);
        assert_eq!(store.used(), 0, "early-stopped reservation fully reclaimed");
        assert_eq!(met.snapshot().early_stopped, 1);
    }
}
