//! Chunk-granular prefill scheduler (replaces the seed's length-bucketed
//! batcher).
//!
//! The unit of scheduling is one *chunk* of one request, not a whole
//! request: every round the scheduler (1) admits new work — resolving the
//! request's bucket, rejecting over-cap requests at admission with a clear
//! error, and reserving the full padded sequence in the paged KV store
//! all-or-nothing (so an admitted request can always run to completion and
//! chunk interleaving cannot deadlock); then (2) dispatches the next chunk
//! of up to `max_inflight` ready requests round-robin across the worker
//! pool.  A 128-chunk prefill therefore no longer head-of-line-blocks a
//! 1-chunk request that arrives behind it: the short request boards the
//! next round and completes while the long one is still mid-sequence.
//!
//! Backends that cannot chunk (PJRT's whole-bucket AOT graphs) run each
//! request as a single chunk through the same rounds, which degrades to the
//! seed's behavior per request while keeping admission/backpressure
//! identical.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};

use crate::util::rng::Rng;

use super::admission::{AdmissionQueue, WorkItem};
use super::engine::{ChunkRun, ChunkStep, PrefillEngine};
use super::kv_cache::PagedKvStore;
use super::metrics::Metrics;
use super::request::PrefillResponse;

/// Scheduler knobs (from `CoordinatorConfig`).
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Default rows per prefill chunk (a request's `chunk` field overrides).
    pub chunk_tokens: usize,
    /// Chunks dispatched per scheduling round — the interleaving width.
    pub max_inflight: usize,
    /// How long to wait for work when idle.
    pub max_wait: std::time::Duration,
}

/// One in-flight request: its chunk state plus the reply channel.
struct Inflight {
    run: ChunkRun,
    reply: mpsc::Sender<PrefillResponse>,
}

/// The scheduler loop: runs on the coordinator's executor thread until
/// `stop` is set and all queues drain.
pub(crate) fn run_loop(
    cfg: &SchedulerConfig,
    engine: &PrefillEngine,
    adm: &AdmissionQueue,
    store: &PagedKvStore,
    met: &Metrics,
    stop: &AtomicBool,
    rng: &mut Rng,
) {
    let mut ready: VecDeque<Inflight> = VecDeque::new();
    loop {
        if stop.load(Ordering::Relaxed) && adm.is_empty() && ready.is_empty() {
            break;
        }
        admit(cfg, engine, adm, store, met, &mut ready, rng);
        if ready.is_empty() {
            if stop.load(Ordering::Relaxed) && adm.is_empty() {
                break;
            }
            continue; // `admit` already waited up to max_wait
        }
        dispatch_round(cfg, engine, store, met, &mut ready);
    }
}

/// Pull new requests out of admission into the ready ring.  Over-cap
/// requests are rejected here — at admission, with a clear error — instead
/// of failing deep in the engine; requests the KV pool cannot hold yet are
/// requeued (backpressure) and admission pauses until blocks free up.
fn admit(
    cfg: &SchedulerConfig,
    engine: &PrefillEngine,
    adm: &AdmissionQueue,
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
    rng: &mut Rng,
) {
    // `max_inflight` bounds admitted requests (each holds a full padded KV
    // reservation), not just chunks per round: a full ready ring admits
    // nothing until something completes.
    let want = cfg.max_inflight.saturating_sub(ready.len());
    if want == 0 {
        return;
    }
    // Only block waiting for work when there is nothing to schedule.
    let wait = if ready.is_empty() { cfg.max_wait } else { std::time::Duration::ZERO };
    let mut pending: VecDeque<WorkItem> = adm.pop_up_to(want, wait).into();
    while let Some(item) = pending.pop_front() {
        let n = item.req.seq_len();
        let Some(bucket) = engine.bucket_for(n) else {
            let largest = engine.buckets().into_iter().max().unwrap_or(0);
            reject(
                met,
                &item,
                format!("rejected at admission: seq_len {n} exceeds largest bucket {largest}"),
            );
            continue;
        };
        if bucket > store.total_blocks * store.block_size {
            // Can NEVER fit, even with the pool idle: requeueing would spin
            // forever and head-of-line-block everything behind it.
            reject(
                met,
                &item,
                format!(
                    "rejected at admission: bucket {bucket} exceeds kv pool capacity ({} blocks x {} rows)",
                    store.total_blocks, store.block_size
                ),
            );
            continue;
        }
        if !store.reserve(item.req.id, bucket) {
            met.kv_rejections.fetch_add(1, Ordering::Relaxed);
            // Pool is full right now: put this item and everything popped
            // behind it back at the FRONT of admission in arrival order,
            // and retry after in-flight work frees blocks.
            pending.push_front(item);
            while let Some(it) = pending.pop_back() {
                adm.requeue(it);
            }
            break;
        }
        let run = engine.begin_chunked(item.req, bucket, cfg.chunk_tokens, rng);
        ready.push_back(Inflight { run, reply: item.reply });
    }
}

/// Fail a request at admission with a clear error.
fn reject(met: &Metrics, item: &WorkItem, msg: String) {
    let resp = PrefillResponse { id: item.req.id, error: Some(msg), ..Default::default() };
    met.record(&resp);
    let _ = item.reply.send(resp);
}

/// Dispatch one chunk for up to `max_inflight` ready requests.  The native
/// backend fans the chunks across the worker pool (each worker runs its
/// chunk's kernels serially — the pool pins nested parallelism to 1);
/// non-parallel backends process the round serially on this thread.
/// Unfinished runs rejoin the BACK of the ready ring, which is what makes
/// scheduling round-robin.
fn dispatch_round(
    cfg: &SchedulerConfig,
    engine: &PrefillEngine,
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
) {
    let take = ready.len().min(cfg.max_inflight.max(1));
    let round: Vec<Inflight> = ready.drain(..take).collect();
    let survivors: Mutex<Vec<Inflight>> = Mutex::new(Vec::with_capacity(take));
    let step = |mut job: Inflight, eng: &PrefillEngine| match eng.process_chunk(&mut job.run, store) {
        ChunkStep::Progress => survivors.lock().unwrap().push(job),
        ChunkStep::Done(resp) => {
            store.free(job.run.req.id);
            met.record(&resp);
            let _ = job.reply.send(resp);
        }
    };
    if engine.supports_parallel() && round.len() > 1 {
        // SAFETY of the Sync wrapper: taken only when supports_parallel()
        // is true, i.e. the Native backend — plain owned data with no
        // interior mutability, and process_chunk takes &self on the engine.
        struct ShareEngine<'a>(&'a PrefillEngine);
        unsafe impl Sync for ShareEngine<'_> {}
        impl<'a> ShareEngine<'a> {
            // Method (not field access) so the closure captures the whole
            // Sync wrapper rather than the inner reference (2021 disjoint
            // capture).
            fn engine(&self) -> &'a PrefillEngine {
                self.0
            }
        }
        let eng = ShareEngine(engine);
        crate::util::parallel::par_drain(round, |job| step(job, eng.engine()));
    } else {
        for job in round {
            step(job, engine);
        }
    }
    // Survivors rejoin in request-id order for determinism (par_drain
    // completes in arbitrary order), behind any newly admitted work that is
    // already queued — round-robin across rounds either way.
    let mut back = survivors.into_inner().unwrap();
    back.sort_by_key(|j| j.run.req.id);
    for job in back {
        ready.push_back(job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineConfig;
    use crate::coordinator::{AttentionMode, PrefillRequest};

    fn setup() -> (SchedulerConfig, PrefillEngine, AdmissionQueue, PagedKvStore, Metrics) {
        let ecfg = EngineConfig::default();
        let engine = PrefillEngine::native_quick(ecfg.clone());
        let store = PagedKvStore::new(256, 64, ecfg.synth.head_dim);
        (
            SchedulerConfig {
                chunk_tokens: 128,
                max_inflight: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
            engine,
            AdmissionQueue::new(64),
            store,
            Metrics::new(),
        )
    }

    fn submit(adm: &AdmissionQueue, id: u64, n: usize) -> mpsc::Receiver<PrefillResponse> {
        let (tx, rx) = mpsc::channel();
        let req = PrefillRequest::synthetic(id, n, id, AttentionMode::Sparse);
        adm.push(WorkItem { req, reply: tx }).unwrap();
        rx
    }

    #[test]
    fn drains_all_work_then_stops() {
        let (cfg, engine, adm, store, met) = setup();
        let rxs: Vec<_> = (0..6).map(|i| submit(&adm, i, 128 + (i as usize % 2) * 128)).collect();
        let stop = AtomicBool::new(true); // pre-set: loop exits once drained
        let mut rng = Rng::new(1);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(rx.recv().unwrap().ok);
        }
        assert_eq!(met.snapshot().completed, 6);
        assert_eq!(store.used(), 0, "all reservations freed");
    }

    #[test]
    fn over_cap_rejected_at_admission() {
        let (cfg, engine, adm, store, met) = setup();
        let rx = submit(&adm, 1, 999_999);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(2);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        let resp = rx.recv().unwrap();
        assert!(!resp.ok);
        let err = resp.error.unwrap();
        assert!(err.contains("rejected at admission"), "{err}");
        assert!(err.contains("exceeds largest bucket"), "{err}");
        assert_eq!(met.snapshot().failed, 1);
        assert_eq!(store.used(), 0);
    }

    #[test]
    fn never_fit_bucket_rejected_not_requeued() {
        let (cfg, engine, adm, big_store, met) = setup();
        // Pool (4 x 64 = 256 rows) smaller than the 512 bucket: the request
        // must be rejected at admission, not requeued forever, and must not
        // block the servable request behind it.
        let store = PagedKvStore::new(4, 64, big_store.head_dim);
        let bad_rx = submit(&adm, 1, 512);
        let ok_rx = submit(&adm, 2, 128);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(4);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        let bad = bad_rx.recv().unwrap();
        assert!(!bad.ok);
        assert!(bad.error.unwrap().contains("exceeds kv pool capacity"));
        assert!(ok_rx.recv().unwrap().ok);
        assert_eq!(met.snapshot().completed, 1);
        assert_eq!(met.snapshot().failed, 1);
    }

    #[test]
    fn kv_exhaustion_requeues_and_recovers() {
        let (cfg, engine, adm, big_store, met) = setup();
        // Pool that fits exactly one 1024-bucket request at a time.
        let store = PagedKvStore::new(16, 64, big_store.head_dim);
        let rxs: Vec<_> = (0..3).map(|i| submit(&adm, i, 1024)).collect();
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(3);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(rx.recv().unwrap().ok, "requeued requests complete eventually");
        }
        let snap = met.snapshot();
        assert_eq!(snap.completed, 3);
        assert!(snap.kv_rejections > 0, "backpressure must have engaged");
    }
}
