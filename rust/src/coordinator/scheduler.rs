//! Continuous-batching scheduler: chunk-granular prefill interleaved with a
//! batched decode stream (the full request lifecycle, vLLM-style).
//!
//! Requests move through three states: *prefilling* (chunk-granular, as in
//! PR 2), *decoding* (one token per round, new K/V appended to the same
//! paged reservation), and *complete* (KV freed, final response sent).
//! Every scheduling round (1) admits new work — resolving the request's
//! bucket, clamping `max_new_tokens` to the coordinator cap, rejecting
//! never-fit requests at admission, and reserving `bucket + max_new` rows
//! in the paged KV store all-or-nothing so an admitted request can always
//! prefill *and* decode to completion; (2) dispatches the next chunk of
//! every prefilling request across the worker pool; and (3) runs one
//! batched decode step across all decoding requests.  Decode streams
//! therefore keep producing tokens while a 128k prefill is mid-sequence —
//! neither direction can starve the other, because both get exactly one
//! round of service per loop iteration.
//!
//! Prefill completions with `max_new_tokens > 0` transition to the decode
//! lane instead of replying; each decode round streams one `TokenFrame`
//! per request through the reply channel, and the final response (tokens,
//! per-token ITL) follows the last frame.  Backends that cannot chunk
//! (PJRT's whole-bucket AOT graphs) never touch the paged store, so their
//! requests complete at prefill and `max_new_tokens` is ignored — decode
//! is a native-backend (paged-store) capability.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};

use crate::util::rng::Rng;

use super::admission::{AdmissionQueue, WorkItem};
use super::engine::{ChunkRun, ChunkStep, DecodeState, DecodeStep, PrefillEngine};
use super::kv_cache::PagedKvStore;
use super::metrics::Metrics;
use super::request::{PrefillResponse, ResponseEvent};

/// Scheduler knobs (from `CoordinatorConfig`).
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Default rows per prefill chunk (a request's `chunk` field overrides).
    pub chunk_tokens: usize,
    /// Requests admitted concurrently (prefilling + decoding) — the
    /// interleaving width and the decode batch-size ceiling.
    pub max_inflight: usize,
    /// How long to wait for work when idle.
    pub max_wait: std::time::Duration,
    /// Server-side cap on per-request `max_new_tokens` (requests asking for
    /// more are clamped at admission).
    pub max_new_cap: usize,
}

/// One prefilling request: its chunk state plus the reply channel.
struct Inflight {
    run: ChunkRun,
    reply: mpsc::Sender<ResponseEvent>,
}

/// The decode batch: states and reply channels, index-aligned (the engine's
/// `decode_round` takes a bare `&mut [DecodeState]`).
#[derive(Default)]
struct DecodeLane {
    states: Vec<DecodeState>,
    replies: Vec<mpsc::Sender<ResponseEvent>>,
}

impl DecodeLane {
    fn len(&self) -> usize {
        self.states.len()
    }

    fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    fn push(&mut self, state: DecodeState, reply: mpsc::Sender<ResponseEvent>) {
        self.states.push(state);
        self.replies.push(reply);
    }
}

/// The scheduler loop: runs on the coordinator's executor thread until
/// `stop` is set and all queues drain.
pub(crate) fn run_loop(
    cfg: &SchedulerConfig,
    engine: &PrefillEngine,
    adm: &AdmissionQueue,
    store: &PagedKvStore,
    met: &Metrics,
    stop: &AtomicBool,
    rng: &mut Rng,
) {
    let mut ready: VecDeque<Inflight> = VecDeque::new();
    let mut decoding = DecodeLane::default();
    loop {
        if stop.load(Ordering::Relaxed) && adm.is_empty() && ready.is_empty() && decoding.is_empty()
        {
            break;
        }
        admit(cfg, engine, adm, store, met, &mut ready, decoding.len(), rng);
        if ready.is_empty() && decoding.is_empty() {
            if stop.load(Ordering::Relaxed) && adm.is_empty() {
                break;
            }
            continue; // `admit` already waited up to max_wait
        }
        // One prefill chunk per prefilling request...
        if !ready.is_empty() {
            dispatch_round(cfg, engine, store, met, &mut ready, &mut decoding);
        }
        // ...and one batched decode step across all decoding requests, every
        // round — decode streams flow while long prefills are mid-sequence.
        if !decoding.is_empty() {
            decode_round(engine, store, met, &mut decoding);
        }
    }
}

/// Pull new requests out of admission into the ready ring.  Over-cap
/// requests are rejected here — at admission, with a clear error — instead
/// of failing deep in the engine; requests the KV pool cannot hold yet are
/// requeued (backpressure) and admission pauses until blocks free up.
fn admit(
    cfg: &SchedulerConfig,
    engine: &PrefillEngine,
    adm: &AdmissionQueue,
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
    decoding: usize,
    rng: &mut Rng,
) {
    // `max_inflight` bounds admitted requests across both lifecycle phases
    // (each holds a full `bucket + max_new` KV reservation): a full system
    // admits nothing until something completes.
    let want = cfg.max_inflight.saturating_sub(ready.len() + decoding);
    if want == 0 {
        return;
    }
    // Only block waiting for work when there is nothing at all to schedule.
    let wait = if ready.is_empty() && decoding == 0 { cfg.max_wait } else { std::time::Duration::ZERO };
    let mut pending: VecDeque<WorkItem> = adm.pop_up_to(want, wait).into();
    while let Some(mut item) = pending.pop_front() {
        let n = item.req.seq_len();
        let Some(bucket) = engine.bucket_for(n) else {
            let largest = engine.buckets().into_iter().max().unwrap_or(0);
            reject(
                met,
                &item,
                format!("rejected at admission: seq_len {n} exceeds largest bucket {largest}"),
            );
            continue;
        };
        // Decode rows live in the same reservation as the prompt, so the
        // clamped token budget is part of the admission footprint.
        item.req.max_new_tokens = item.req.max_new_tokens.min(cfg.max_new_cap);
        if !engine.supports_chunked() {
            // Non-chunked backends (PJRT's whole-bucket graphs) never touch
            // the paged store and complete at prefill: don't reserve — or
            // reject for — decode rows that can never be used.
            item.req.max_new_tokens = 0;
        }
        let rows = bucket + item.req.max_new_tokens;
        if rows > store.total_blocks * store.block_size {
            // Can NEVER fit, even with the pool idle: requeueing would spin
            // forever and head-of-line-block everything behind it.
            reject(
                met,
                &item,
                format!(
                    "rejected at admission: bucket {bucket} + {} new tokens exceeds kv pool capacity ({} blocks x {} rows)",
                    item.req.max_new_tokens, store.total_blocks, store.block_size
                ),
            );
            continue;
        }
        if !store.reserve(item.req.id, rows) {
            met.kv_rejections.fetch_add(1, Ordering::Relaxed);
            // Pool is full right now: put this item and everything popped
            // behind it back at the FRONT of admission in arrival order,
            // and retry after in-flight work frees blocks.
            pending.push_front(item);
            while let Some(it) = pending.pop_back() {
                adm.requeue(it);
            }
            break;
        }
        let run = engine.begin_chunked(item.req, bucket, cfg.chunk_tokens, rng);
        ready.push_back(Inflight { run, reply: item.reply });
    }
}

/// Fail a request at admission with a clear error.
fn reject(met: &Metrics, item: &WorkItem, msg: String) {
    let resp = PrefillResponse { id: item.req.id, error: Some(msg), ..Default::default() };
    met.record(&resp);
    let _ = item.reply.send(ResponseEvent::Done(resp));
}

/// Dispatch one chunk for up to `max_inflight` ready requests.  The native
/// backend fans the chunks across the worker pool (each worker runs its
/// chunk's kernels serially — the pool pins nested parallelism to 1);
/// non-parallel backends process the round serially on this thread.
/// Unfinished runs rejoin the BACK of the ready ring, which is what makes
/// scheduling round-robin; finished runs that requested tokens transition
/// to the decode lane with their KV reservation intact.
fn dispatch_round(
    cfg: &SchedulerConfig,
    engine: &PrefillEngine,
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
    decoding: &mut DecodeLane,
) {
    let take = ready.len().min(cfg.max_inflight.max(1));
    let round: Vec<Inflight> = ready.drain(..take).collect();
    let survivors: Mutex<Vec<Inflight>> = Mutex::new(Vec::with_capacity(take));
    let entering_decode: Mutex<Vec<(DecodeState, mpsc::Sender<ResponseEvent>)>> =
        Mutex::new(Vec::new());
    let step = |mut job: Inflight, eng: &PrefillEngine| match eng.process_chunk(&mut job.run, store)
    {
        ChunkStep::Progress => survivors.lock().unwrap().push(job),
        ChunkStep::Done(resp) => {
            // Only the chunked (paged-store) path can decode: the monolithic
            // fallback never appended K/V, so it completes at prefill.
            if resp.ok && job.run.req.max_new_tokens > 0 && eng.supports_chunked() {
                let Inflight { run, reply } = job;
                let state = eng.begin_decode(run, resp);
                entering_decode.lock().unwrap().push((state, reply));
            } else {
                store.free(job.run.req.id);
                met.record(&resp);
                let _ = job.reply.send(ResponseEvent::Done(resp));
            }
        }
    };
    if engine.supports_parallel() && round.len() > 1 {
        // SAFETY of the Sync wrapper: taken only when supports_parallel()
        // is true, i.e. the Native backend — plain owned data with no
        // interior mutability, and process_chunk takes &self on the engine.
        struct ShareEngine<'a>(&'a PrefillEngine);
        unsafe impl Sync for ShareEngine<'_> {}
        impl<'a> ShareEngine<'a> {
            // Method (not field access) so the closure captures the whole
            // Sync wrapper rather than the inner reference (2021 disjoint
            // capture).
            fn engine(&self) -> &'a PrefillEngine {
                self.0
            }
        }
        let eng = ShareEngine(engine);
        crate::util::parallel::par_drain(round, |job| step(job, eng.engine()));
    } else {
        for job in round {
            step(job, engine);
        }
    }
    // Survivors and decode entrants rejoin in request-id order for
    // determinism (par_drain completes in arbitrary order).
    let mut back = survivors.into_inner().unwrap();
    back.sort_by_key(|j| j.run.req.id);
    for job in back {
        ready.push_back(job);
    }
    let mut entrants = entering_decode.into_inner().unwrap();
    entrants.sort_by_key(|(s, _)| s.req.id);
    for (state, reply) in entrants {
        decoding.push(state, reply);
    }
}

/// One batched decode step: every decoding request generates its next token
/// (the engine fans the batch's attention across the worker pool), frames
/// stream out as soon as they exist, and finished requests free their KV and
/// reply.
fn decode_round(
    engine: &PrefillEngine,
    store: &PagedKvStore,
    met: &Metrics,
    decoding: &mut DecodeLane,
) {
    let steps = engine.decode_round(&mut decoding.states, store);
    let states = std::mem::take(&mut decoding.states);
    let replies = std::mem::take(&mut decoding.replies);
    for ((state, reply), step) in states.into_iter().zip(replies).zip(steps) {
        match step {
            DecodeStep::Token(frame) => {
                let _ = reply.send(ResponseEvent::Token(frame));
                decoding.push(state, reply);
            }
            DecodeStep::Done(frame, resp) => {
                let _ = reply.send(ResponseEvent::Token(frame));
                store.free(state.req.id);
                met.record(&resp);
                let _ = reply.send(ResponseEvent::Done(resp));
            }
            DecodeStep::Failed(resp) => {
                store.free(state.req.id);
                met.record(&resp);
                let _ = reply.send(ResponseEvent::Done(resp));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineConfig;
    use crate::coordinator::{AttentionMode, PrefillRequest};

    fn setup() -> (SchedulerConfig, PrefillEngine, AdmissionQueue, PagedKvStore, Metrics) {
        let ecfg = EngineConfig::default();
        let engine = PrefillEngine::native_quick(ecfg.clone());
        let store = PagedKvStore::new(256, 64, ecfg.synth.head_dim);
        (
            SchedulerConfig {
                chunk_tokens: 128,
                max_inflight: 8,
                max_wait: std::time::Duration::from_millis(1),
                max_new_cap: 256,
            },
            engine,
            AdmissionQueue::new(64),
            store,
            Metrics::new(),
        )
    }

    fn submit(adm: &AdmissionQueue, id: u64, n: usize) -> mpsc::Receiver<ResponseEvent> {
        submit_gen(adm, id, n, 0)
    }

    fn submit_gen(
        adm: &AdmissionQueue,
        id: u64,
        n: usize,
        max_new: usize,
    ) -> mpsc::Receiver<ResponseEvent> {
        let (tx, rx) = mpsc::channel();
        let mut req = PrefillRequest::synthetic(id, n, id, AttentionMode::Sparse);
        req.max_new_tokens = max_new;
        adm.push(WorkItem { req, reply: tx }).unwrap();
        rx
    }

    /// Drain a reply stream to its final response, counting token frames.
    fn final_of(rx: &mpsc::Receiver<ResponseEvent>) -> (usize, PrefillResponse) {
        let mut frames = 0;
        loop {
            match rx.recv().unwrap() {
                ResponseEvent::Token(_) => frames += 1,
                ResponseEvent::Done(resp) => return (frames, resp),
            }
        }
    }

    #[test]
    fn drains_all_work_then_stops() {
        let (cfg, engine, adm, store, met) = setup();
        let rxs: Vec<_> = (0..6).map(|i| submit(&adm, i, 128 + (i as usize % 2) * 128)).collect();
        let stop = AtomicBool::new(true); // pre-set: loop exits once drained
        let mut rng = Rng::new(1);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(final_of(&rx).1.ok);
        }
        assert_eq!(met.snapshot().completed, 6);
        assert_eq!(store.used(), 0, "all reservations freed");
    }

    #[test]
    fn over_cap_rejected_at_admission() {
        let (cfg, engine, adm, store, met) = setup();
        let rx = submit(&adm, 1, 999_999);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(2);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        let (_, resp) = final_of(&rx);
        assert!(!resp.ok);
        let err = resp.error.unwrap();
        assert!(err.contains("rejected at admission"), "{err}");
        assert!(err.contains("exceeds largest bucket"), "{err}");
        assert_eq!(met.snapshot().failed, 1);
        assert_eq!(store.used(), 0);
    }

    #[test]
    fn never_fit_bucket_rejected_not_requeued() {
        let (cfg, engine, adm, big_store, met) = setup();
        // Pool (4 x 64 = 256 rows) smaller than the 512 bucket: the request
        // must be rejected at admission, not requeued forever, and must not
        // block the servable request behind it.
        let store = PagedKvStore::new(4, 64, big_store.head_dim);
        let bad_rx = submit(&adm, 1, 512);
        let ok_rx = submit(&adm, 2, 128);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(4);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        let (_, bad) = final_of(&bad_rx);
        assert!(!bad.ok);
        assert!(bad.error.unwrap().contains("exceeds kv pool capacity"));
        assert!(final_of(&ok_rx).1.ok);
        assert_eq!(met.snapshot().completed, 1);
        assert_eq!(met.snapshot().failed, 1);
    }

    #[test]
    fn decode_footprint_counts_against_pool_capacity() {
        let (cfg, engine, adm, big_store, met) = setup();
        // Pool of exactly 256 rows: a 256-row prompt fits alone, but the
        // same prompt + 10 decode tokens can never fit and must be rejected
        // at admission (the reservation covers prompt + max_new).
        let store = PagedKvStore::new(4, 64, big_store.head_dim);
        let bad_rx = submit_gen(&adm, 1, 256, 10);
        let ok_rx = submit_gen(&adm, 2, 256, 0);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(5);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        let (_, bad) = final_of(&bad_rx);
        assert!(!bad.ok);
        assert!(bad.error.unwrap().contains("new tokens exceeds kv pool capacity"));
        assert!(final_of(&ok_rx).1.ok);
    }

    #[test]
    fn kv_exhaustion_requeues_and_recovers() {
        let (cfg, engine, adm, big_store, met) = setup();
        // Pool that fits exactly one 1024-bucket request at a time.
        let store = PagedKvStore::new(16, 64, big_store.head_dim);
        let rxs: Vec<_> = (0..3).map(|i| submit(&adm, i, 1024)).collect();
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(3);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(final_of(&rx).1.ok, "requeued requests complete eventually");
        }
        let snap = met.snapshot();
        assert_eq!(snap.completed, 3);
        assert!(snap.kv_rejections > 0, "backpressure must have engaged");
    }

    #[test]
    fn generation_streams_frames_then_final_response() {
        let (cfg, engine, adm, store, met) = setup();
        let rx = submit_gen(&adm, 1, 128, 5);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(6);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        let (frames, resp) = final_of(&rx);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(frames, 5, "one streamed frame per generated token");
        assert_eq!(resp.tokens.len(), 5);
        assert_eq!(resp.decode_us.len(), 5);
        assert_eq!(store.used(), 0, "prompt + decode reservation freed");
        let snap = met.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.tokens_generated, 5);
    }

    #[test]
    fn max_new_tokens_clamped_to_cap() {
        let (mut cfg, engine, adm, store, met) = setup();
        cfg.max_new_cap = 3;
        let rx = submit_gen(&adm, 1, 128, 100);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(7);
        run_loop(&cfg, &engine, &adm, &store, &met, &stop, &mut rng);
        let (frames, resp) = final_of(&rx);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 3, "clamped to max_new_cap");
        assert_eq!(frames, 3);
    }
}
