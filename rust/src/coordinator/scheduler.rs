//! Continuous-batching scheduler: chunk-granular prefill interleaved with a
//! batched decode stream (the full request lifecycle, vLLM-style), driven
//! entirely through `dyn ExecBackend`.
//!
//! Requests move through the typed [`RunState`] lifecycle: *prefilling*
//! (chunk-granular), *decoding* (one token per round, new K/V appended to
//! the same paged reservation), and *finished* (KV freed, final response
//! sent).  Every scheduling round (0) **reaps** overloaded work — requests
//! whose client cancelled ([`PrefillRequest::cancel`]) or whose deadline
//! ([`PrefillRequest::deadline_ms`]) passed are cut short *between* backend
//! calls, in either lifecycle phase, their paged reservation freed
//! immediately and a typed terminal response ([`Outcome::Cancelled`] /
//! [`Outcome::Expired`]) sent; (1) admits new work — screening out
//! already-cancelled and already-expired requests, resolving the request's
//! bucket, clamping `max_new_tokens` to the coordinator cap (and to zero
//! for backends without the decode capability), rejecting never-fit
//! requests at admission with [`Outcome::Rejected`], and — for backends
//! with the `chunked` capability, the only ones that touch the paged store
//! — reserving `bucket + max_new` rows in the paged KV store
//! all-or-nothing so an admitted request can always prefill *and* decode
//! to completion.
//! With the prefix cache on, the reservation first probes the store's
//! shared-prefix index with the backend's content chain
//! ([`ExecBackend::prefix_chain`]): already-resident leading prompt
//! blocks are pinned (shared) instead of re-reserved, the hit rides into
//! [`ExecBackend::begin`] so the backend resumes past the cached rows,
//! and `prefix_hits` / `prefix_blocks_shared` / `prefix_evictions` land
//! in the metrics.  A request whose prompt is *currently being prefilled*
//! by another in-flight request (the store's in-flight registry says so)
//! is deferred instead of admitted cold: the leader publishes its groups
//! chunk by chunk, and the follower admits warm once the full prompt is
//! resident — concurrent identical prompts cost one prefill, not N;
//! (2) dispatches the next chunk of
//! every prefilling request — across the worker pool when the backend's
//! [`Capabilities`] allow sharing, serially otherwise; and (3) runs one
//! batched decode step across all decoding requests.  Decode streams
//! therefore keep producing tokens while a 128k prefill is mid-sequence —
//! neither direction can starve the other, because both get exactly one
//! round of service per loop iteration.
//!
//! KV backpressure (a reservation that cannot be placed *right now*)
//! requeues the work and backs admission off exponentially (1 ms doubling
//! to a 16 ms cap, counted in `requeue_rounds`) instead of hot-spinning
//! the pop/requeue cycle; the backoff only ever sleeps when there is no
//! active run to make progress on, and resets the moment a reservation
//! lands.
//!
//! The scheduler never inspects which backend it is running: everything it
//! needs to know (chunked? parallel? decode? largest bucket?) comes from
//! [`Capabilities`], and the prefill -> decode transition is the backend's
//! call ([`ChunkStep::EnterDecode`]) — there is no capability probing or
//! feature-gated dispatch here.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use crate::util::rng::Rng;

use super::admission::{AdmissionQueue, WorkItem};
use super::backend::{Capabilities, ChunkStep, DecodeStep, ExecBackend, RunState};
use super::kv_cache::PagedKvStore;
use super::metrics::Metrics;
use super::request::{
    Outcome, PrefillRequest, PrefillResponse, Priority, RejectReason, ResponseEvent,
};

/// Scheduler knobs (from `CoordinatorConfig`).
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Default rows per prefill chunk (a request's `chunk` field overrides).
    pub chunk_tokens: usize,
    /// Requests admitted concurrently (prefilling + decoding) — the
    /// interleaving width and the decode batch-size ceiling.
    pub max_inflight: usize,
    /// How long to wait for work when idle.
    pub max_wait: std::time::Duration,
    /// Server-side cap on per-request `max_new_tokens` (requests asking for
    /// more are clamped at admission).
    pub max_new_cap: usize,
    /// Probe the paged store's shared-prefix index at admission and pin
    /// already-resident prompt blocks into new reservations (chunked
    /// backends only).
    pub prefix_cache: bool,
}

/// One prefilling request: its run state plus the reply channel.
struct Inflight {
    run: RunState,
    reply: mpsc::Sender<ResponseEvent>,
}

/// The decode batch: runs and reply channels, index-aligned (the backend's
/// `decode_step` takes a bare `&mut [RunState]`).
#[derive(Default)]
struct DecodeLane {
    runs: Vec<RunState>,
    replies: Vec<mpsc::Sender<ResponseEvent>>,
}

impl DecodeLane {
    fn len(&self) -> usize {
        self.runs.len()
    }

    fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    fn push(&mut self, run: RunState, reply: mpsc::Sender<ResponseEvent>) {
        self.runs.push(run);
        self.replies.push(reply);
    }

    /// Remove the run at `i` together with its reply channel (the two vecs
    /// stay index-aligned by construction).
    fn remove(&mut self, i: usize) -> (RunState, mpsc::Sender<ResponseEvent>) {
        (self.runs.remove(i), self.replies.remove(i))
    }
}

/// Admission backpressure state: how hard the last KV-exhaustion round hit
/// and when admission may try again.
#[derive(Default)]
struct AdmitState {
    /// Current exponential-backoff step (0 = no backoff pending).
    backoff_ms: u64,
    /// Admission pauses until this instant (KV-exhaustion backoff).
    next_at: Option<Instant>,
}

/// The scheduler loop: runs on the coordinator's executor thread until
/// `stop` is set and all queues drain.
pub(crate) fn run_loop(
    cfg: &SchedulerConfig,
    backend: &dyn ExecBackend,
    adm: &AdmissionQueue,
    store: &PagedKvStore,
    met: &Metrics,
    stop: &AtomicBool,
    rng: &mut Rng,
) {
    let caps = backend.capabilities();
    // `max_bucket` is the second copy of what `buckets()` already says;
    // enforce the single-source invariant once, loudly, so an out-of-tree
    // backend cannot ship an inconsistent pair (the admission error message
    // cites `max_bucket`, the admission decision uses `bucket_for`).
    assert_eq!(
        Some(caps.max_bucket),
        backend.buckets().iter().copied().max(),
        "backend '{}' reports max_bucket inconsistent with its bucket list",
        backend.name()
    );
    let mut ready: VecDeque<Inflight> = VecDeque::new();
    let mut decoding = DecodeLane::default();
    let mut st = AdmitState::default();
    loop {
        if stop.load(Ordering::Relaxed) && adm.is_empty() && ready.is_empty() && decoding.is_empty()
        {
            break;
        }
        // Reap cancelled/expired work FIRST: their reservations return to
        // the pool before this round's admission tries to place new work.
        reap(store, met, &mut ready, &mut decoding);
        admit(cfg, backend, &caps, adm, store, met, &mut ready, decoding.len(), &mut st, rng);
        if ready.is_empty() && decoding.is_empty() {
            if stop.load(Ordering::Relaxed) && adm.is_empty() {
                break;
            }
            continue; // `admit` already waited up to max_wait
        }
        // One prefill chunk per prefilling request...
        if !ready.is_empty() {
            dispatch_round(cfg, backend, &caps, store, met, &mut ready, &mut decoding);
        }
        // ...and one batched decode step across all decoding requests, every
        // round — decode streams flow while long prefills are mid-sequence.
        if !decoding.is_empty() {
            decode_round(backend, store, met, &mut decoding);
        }
    }
}

/// Whether `req` should be cut short right now, and how to label it.
fn overload_of(req: &PrefillRequest, now: Instant) -> Option<(Outcome, String)> {
    if req.cancel.is_cancelled() {
        return Some((Outcome::Cancelled, format!("request {} cancelled by client", req.id)));
    }
    if req.expired(now) {
        return Some((
            Outcome::Expired,
            format!(
                "request {} exceeded its {} ms deadline",
                req.id,
                req.deadline_ms.unwrap_or(0)
            ),
        ));
    }
    None
}

/// Cut cancelled/expired runs short between backend calls — in *either*
/// lifecycle phase — freeing their paged reservation immediately and
/// sending the typed terminal response.  This is the only place admitted
/// work exits the lifecycle other than the backend's own terminal steps,
/// so every admitted request leaves through exactly one of four doors:
/// done, stopped, expired, cancelled.
fn reap(
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
    decoding: &mut DecodeLane,
) {
    let now = Instant::now();
    let mut i = 0;
    while i < ready.len() {
        match overload_of(ready[i].run.request(), now) {
            Some((outcome, msg)) => {
                let mut job = ready.remove(i).expect("index in bounds");
                store.free(job.run.id());
                let resp = job.run.finish_overload(outcome, msg);
                met.record(&resp);
                let _ = job.reply.send(ResponseEvent::Done(resp));
            }
            None => i += 1,
        }
    }
    let mut i = 0;
    while i < decoding.runs.len() {
        match overload_of(decoding.runs[i].request(), now) {
            Some((outcome, msg)) => {
                let (mut run, reply) = decoding.remove(i);
                store.free(run.id());
                let resp = run.finish_overload(outcome, msg);
                met.record(&resp);
                let _ = reply.send(ResponseEvent::Done(resp));
            }
            None => i += 1,
        }
    }
}

/// Pull new requests out of admission into the ready ring.  Over-cap
/// requests are rejected here — at admission, with a typed outcome and a
/// clear error — instead of failing deep in the backend; requests the KV
/// pool cannot hold yet are requeued (backpressure) and admission backs
/// off exponentially until blocks free up; requests whose exact prompt is
/// mid-prefill on another in-flight request are deferred so they admit
/// warm from the leader's published blocks instead of running cold.
#[allow(clippy::too_many_arguments)]
fn admit(
    cfg: &SchedulerConfig,
    backend: &dyn ExecBackend,
    caps: &Capabilities,
    adm: &AdmissionQueue,
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
    decoding: usize,
    st: &mut AdmitState,
    rng: &mut Rng,
) {
    // KV-exhaustion backoff: when the last round could not place a
    // reservation, pause admission instead of hot-spinning pop/requeue.
    // Only sleep when there is no admitted work to make progress on —
    // otherwise skip this round and let dispatch/decode free blocks.
    if let Some(t) = st.next_at {
        let now = Instant::now();
        if now < t {
            if ready.is_empty() && decoding == 0 {
                std::thread::sleep(t.saturating_duration_since(now));
            } else {
                return;
            }
        }
        st.next_at = None;
    }
    // `max_inflight` bounds admitted requests across both lifecycle phases
    // (each holds a full `bucket + max_new` KV reservation): a full system
    // admits nothing until something completes.
    let want = cfg.max_inflight.saturating_sub(ready.len() + decoding);
    if want == 0 {
        return;
    }
    // Only block waiting for work when there is nothing at all to schedule.
    let wait =
        if ready.is_empty() && decoding == 0 { cfg.max_wait } else { std::time::Duration::ZERO };
    let mut popped = adm.pop_up_to(want, wait);
    // Admission order: interactive ahead of batch, always; when the pool is
    // tight, requests with more resident prefix rows first (they pin shared
    // blocks instead of consuming fresh ones, so they are the cheapest way
    // to drain the queue).  The sort is stable: arrival order breaks ties.
    if popped.len() > 1 {
        let tight = store.used() * 2 >= store.total_blocks;
        popped.sort_by_key(|it| {
            let class = match it.req.priority {
                Priority::Interactive => 0u8,
                Priority::Batch => 1,
            };
            let resident = if tight && cfg.prefix_cache && caps.chunked {
                // Served from the item's generation-keyed cache (see
                // [`WorkItem::probe`]): the store is only re-probed for
                // items whose last answer predates a prefix-state change —
                // not for every queued item on every pressure round.
                it.probe(backend, store).resident_rows
            } else {
                0
            };
            (class, std::cmp::Reverse(resident))
        });
    }
    let mut pending: VecDeque<WorkItem> = popped.into();
    let mut deferred: Vec<WorkItem> = Vec::new();
    let now = Instant::now();
    while let Some(mut item) = pending.pop_front() {
        // Overload screening before any placement work: a request that was
        // cancelled or whose deadline passed while queued never reserves.
        if item.req.cancel.is_cancelled() {
            reject(
                met,
                &item,
                Outcome::Cancelled,
                None,
                format!("request {} cancelled before admission", item.req.id),
            );
            continue;
        }
        if item.req.expired(now) {
            reject(
                met,
                &item,
                Outcome::Rejected(RejectReason::DeadlineInfeasible),
                None,
                format!(
                    "rejected at admission: request {} deadline ({} ms) already expired",
                    item.req.id,
                    item.req.deadline_ms.unwrap_or(0)
                ),
            );
            continue;
        }
        let n = item.req.seq_len();
        let Some(bucket) = backend.bucket_for(n) else {
            reject(
                met,
                &item,
                Outcome::Rejected(RejectReason::OverCapacity),
                None,
                format!(
                    "rejected at admission: seq_len {n} exceeds largest bucket {}",
                    caps.max_bucket
                ),
            );
            continue;
        };
        // Decode rows live in the same reservation as the prompt, so the
        // clamped token budget is part of the admission footprint.
        item.req.max_new_tokens = item.req.max_new_tokens.min(cfg.max_new_cap);
        if !caps.decode {
            // Backends without the decode capability complete at prefill:
            // don't reserve — or reject for — decode rows that can never be
            // used.
            item.req.max_new_tokens = 0;
        }
        // Only chunked backends touch the paged store: reserving rows for a
        // backend that executes monolithically would strand pool capacity
        // on pure accounting (and spuriously reject on small pools).
        let mut prefix: Option<super::backend::PrefixHit> = None;
        if caps.chunked {
            let rows = bucket + item.req.max_new_tokens;
            if rows > store.total_blocks * store.block_size {
                // Can NEVER fit, even with the pool idle: requeueing would
                // spin forever and head-of-line-block everything behind it.
                reject(
                    met,
                    &item,
                    Outcome::Rejected(RejectReason::OverCapacity),
                    None,
                    format!(
                        "rejected at admission: bucket {bucket} + {} new tokens exceeds kv pool capacity ({} blocks x {} rows)",
                        item.req.max_new_tokens, store.total_blocks, store.block_size
                    ),
                );
                continue;
            }
            // Prefix-cache admission: probe the store's index with the
            // request's content chain (hashed once per queued item, cloned
            // out of the item's cache here); matching leading blocks are
            // pinned (shared) into the reservation and only the tail is
            // fresh.
            let chain = if cfg.prefix_cache {
                item.chain(backend, store.block_size).cloned()
            } else {
                None
            };
            if let Some(c) = &chain {
                // In-flight coalescing: if another request is prefilling
                // this exact prompt right now, defer instead of starting a
                // duplicate cold prefill.  The leader publishes its groups
                // after every chunk — each publish bumps the store's prefix
                // generation, so the deferred follower's cached probe
                // refreshes and its resident count grows each round until
                // it admits with a full hit once the leader's prompt is
                // resident (or cold if the leader died — `free` clears its
                // claim).  No backoff: the leader itself makes progress
                // every scheduler round.
                let probe = item.probe(backend, store);
                if probe.inflight && probe.resident_rows < c.rows() {
                    deferred.push(item);
                    continue;
                }
            }
            let outcome = store.reserve_with_prefix(item.req.id, rows, chain.as_ref());
            met.prefix_evictions.fetch_add(outcome.evicted as u64, Ordering::Relaxed);
            if !outcome.reserved {
                met.kv_rejections.fetch_add(1, Ordering::Relaxed);
                met.requeue_rounds.fetch_add(1, Ordering::Relaxed);
                // Pool is full right now: put this item and everything
                // popped behind it back at the FRONT of admission in
                // arrival order, back off, and retry after in-flight work
                // frees blocks.
                st.backoff_ms = if st.backoff_ms == 0 { 1 } else { (st.backoff_ms * 2).min(16) };
                st.next_at = Some(Instant::now() + Duration::from_millis(st.backoff_ms));
                pending.push_front(item);
                while let Some(it) = pending.pop_back() {
                    adm.requeue(it);
                }
                break;
            }
            st.backoff_ms = 0;
            st.next_at = None;
            if outcome.hit_rows > 0 {
                met.prefix_hits.fetch_add(1, Ordering::Relaxed);
                met.prefix_blocks_shared.fetch_add(outcome.hit_blocks as u64, Ordering::Relaxed);
            }
            prefix = chain.map(|chain| super::backend::PrefixHit {
                chain,
                rows: outcome.hit_rows,
                aux: outcome.aux,
            });
        }
        let run = backend.begin(item.req, bucket, cfg.chunk_tokens, prefix, rng);
        ready.push_back(Inflight { run, reply: item.reply });
    }
    // Deferred followers go back to the front (they were popped first) and
    // are re-probed next round against the leader's grown resident run.
    for it in deferred.into_iter().rev() {
        adm.requeue(it);
    }
}

/// Fail a request at admission with a typed outcome and a clear error.
fn reject(
    met: &Metrics,
    item: &WorkItem,
    outcome: Outcome,
    retry_after_ms: Option<u64>,
    msg: String,
) {
    let resp = PrefillResponse {
        id: item.req.id,
        error: Some(msg),
        outcome,
        retry_after_ms,
        ..Default::default()
    };
    met.record(&resp);
    let _ = item.reply.send(ResponseEvent::Done(resp));
}

/// Dispatch one chunk for up to `max_inflight` ready requests.  Backends
/// with the `parallel` capability fan the chunks across the worker pool
/// (each worker runs its chunk's kernels serially — the pool pins nested
/// parallelism to 1); others process the round serially on this thread.
/// Unfinished runs rejoin the BACK of the ready ring, which is what makes
/// scheduling round-robin; runs the backend transitioned into the decode
/// phase ([`ChunkStep::EnterDecode`]) move to the decode lane with their KV
/// reservation intact.
fn dispatch_round(
    cfg: &SchedulerConfig,
    backend: &dyn ExecBackend,
    caps: &Capabilities,
    store: &PagedKvStore,
    met: &Metrics,
    ready: &mut VecDeque<Inflight>,
    decoding: &mut DecodeLane,
) {
    let take = ready.len().min(cfg.max_inflight.max(1));
    let round: Vec<Inflight> = ready.drain(..take).collect();
    let survivors: Mutex<Vec<Inflight>> = Mutex::new(Vec::with_capacity(take));
    let entering_decode: Mutex<Vec<Inflight>> = Mutex::new(Vec::new());
    let step = |mut job: Inflight, b: &dyn ExecBackend| match b.prefill_chunk(&mut job.run, store)
    {
        ChunkStep::Progress => survivors.lock().expect("round sink poisoned").push(job),
        ChunkStep::EnterDecode => entering_decode.lock().expect("round sink poisoned").push(job),
        ChunkStep::Done(resp) => {
            store.free(job.run.id());
            met.record(&resp);
            let _ = job.reply.send(ResponseEvent::Done(resp));
        }
    };
    if caps.parallel() && round.len() > 1 {
        struct ShareBackend<'a>(&'a dyn ExecBackend);
        // SAFETY: constructed only when the backend opted into parallel
        // dispatch through the *unsafe*
        // `Capabilities::with_parallel_dispatch`, whose contract is exactly
        // this — `&self` is soundly shareable across threads (plain owned
        // data, no interior mutability); `prefill_chunk` takes `&self`.
        unsafe impl Sync for ShareBackend<'_> {}
        impl<'a> ShareBackend<'a> {
            // Method (not field access) so the closure captures the whole
            // Sync wrapper rather than the inner reference (2021 disjoint
            // capture).
            fn backend(&self) -> &'a dyn ExecBackend {
                self.0
            }
        }
        let b = ShareBackend(backend);
        crate::util::parallel::par_drain(round, |job| step(job, b.backend()));
    } else {
        for job in round {
            step(job, backend);
        }
    }
    // Survivors and decode entrants rejoin in request-id order for
    // determinism (par_drain completes in arbitrary order).
    let mut back = survivors.into_inner().expect("round sink poisoned");
    back.sort_by_key(|j| j.run.id());
    for job in back {
        ready.push_back(job);
    }
    let mut entrants = entering_decode.into_inner().expect("round sink poisoned");
    entrants.sort_by_key(|j| j.run.id());
    for Inflight { run, reply } in entrants {
        debug_assert!(run.is_decoding(), "EnterDecode must leave the run in the decode phase");
        decoding.push(run, reply);
    }
}

/// One batched decode step: every decoding request generates its next token
/// (the backend may fan the batch across the worker pool), frames stream
/// out as soon as they exist, and finished requests free their KV and
/// reply.  A client that stopped reading its stream (the frame send fails)
/// raises the request's own cancel flag, so the next reap round cuts the
/// generation short instead of decoding into a void.
fn decode_round(
    backend: &dyn ExecBackend,
    store: &PagedKvStore,
    met: &Metrics,
    decoding: &mut DecodeLane,
) {
    let steps = backend.decode_step(&mut decoding.runs, store);
    assert_eq!(
        steps.len(),
        decoding.runs.len(),
        "backend '{}' broke the decode_step contract: one index-aligned DecodeStep per run",
        backend.name()
    );
    let runs = std::mem::take(&mut decoding.runs);
    let replies = std::mem::take(&mut decoding.replies);
    for ((run, reply), step) in runs.into_iter().zip(replies).zip(steps) {
        match step {
            DecodeStep::Token(frame) => {
                if reply.send(ResponseEvent::Token(frame)).is_err() {
                    // Receiver gone mid-stream: treat it as a client
                    // cancellation — the reap pass frees the reservation.
                    run.request().cancel.cancel();
                }
                decoding.push(run, reply);
            }
            DecodeStep::Done(frame, resp) => {
                let _ = reply.send(ResponseEvent::Token(frame));
                store.free(run.id());
                met.record(&resp);
                let _ = reply.send(ResponseEvent::Done(resp));
            }
            DecodeStep::Failed(resp) => {
                store.free(run.id());
                met.record(&resp);
                let _ = reply.send(ResponseEvent::Done(resp));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::native::NativeBackend;
    use crate::coordinator::backend::reference::ReferenceBackend;
    use crate::coordinator::engine::EngineConfig;
    use crate::coordinator::{AttentionMode, PrefillRequest};

    fn setup() -> (SchedulerConfig, NativeBackend, AdmissionQueue, PagedKvStore, Metrics) {
        let ecfg = EngineConfig::default();
        let backend = NativeBackend::quick(ecfg.clone());
        let store = PagedKvStore::new(256, 64, ecfg.synth.head_dim);
        (
            SchedulerConfig {
                chunk_tokens: 128,
                max_inflight: 8,
                max_wait: std::time::Duration::from_millis(1),
                max_new_cap: 256,
                prefix_cache: true,
            },
            backend,
            AdmissionQueue::new(64, 64),
            store,
            Metrics::new(),
        )
    }

    fn submit(adm: &AdmissionQueue, id: u64, n: usize) -> mpsc::Receiver<ResponseEvent> {
        submit_gen(adm, id, n, 0)
    }

    fn submit_gen(
        adm: &AdmissionQueue,
        id: u64,
        n: usize,
        max_new: usize,
    ) -> mpsc::Receiver<ResponseEvent> {
        let (tx, rx) = mpsc::channel();
        let mut req = PrefillRequest::synthetic(id, n, id, AttentionMode::Sparse);
        req.max_new_tokens = max_new;
        adm.push(WorkItem::new(req, tx)).unwrap();
        rx
    }

    /// Drain a reply stream to its final response, counting token frames.
    fn final_of(rx: &mpsc::Receiver<ResponseEvent>) -> (usize, PrefillResponse) {
        let mut frames = 0;
        loop {
            match rx.recv().unwrap() {
                ResponseEvent::Token(_) => frames += 1,
                ResponseEvent::Done(resp) => return (frames, resp),
            }
        }
    }

    #[test]
    fn drains_all_work_then_stops() {
        let (cfg, backend, adm, store, met) = setup();
        let rxs: Vec<_> = (0..6).map(|i| submit(&adm, i, 128 + (i as usize % 2) * 128)).collect();
        let stop = AtomicBool::new(true); // pre-set: loop exits once drained
        let mut rng = Rng::new(1);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(final_of(&rx).1.ok);
        }
        assert_eq!(met.snapshot().completed, 6);
        assert_eq!(store.used(), 0, "all reservations freed");
    }

    #[test]
    fn serial_backend_drains_the_same_workload() {
        // The reference backend reports `parallel: false`, driving the
        // scheduler's serial dispatch path through the identical lifecycle.
        let (cfg, _backend, adm, store, met) = setup();
        let backend = ReferenceBackend::quick(EngineConfig::default());
        assert!(!backend.capabilities().parallel());
        let rxs: Vec<_> = (0..4).map(|i| submit(&adm, i, 128)).collect();
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(8);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(final_of(&rx).1.ok);
        }
        assert_eq!(met.snapshot().completed, 4);
        assert_eq!(store.used(), 0);
    }

    #[test]
    fn over_cap_rejected_at_admission() {
        let (cfg, backend, adm, store, met) = setup();
        let rx = submit(&adm, 1, 999_999);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(2);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, resp) = final_of(&rx);
        assert!(!resp.ok);
        assert_eq!(resp.outcome, Outcome::Rejected(RejectReason::OverCapacity));
        let err = resp.error.unwrap();
        assert!(err.contains("rejected at admission"), "{err}");
        assert!(err.contains("exceeds largest bucket"), "{err}");
        assert_eq!(met.snapshot().failed, 1);
        assert_eq!(store.used(), 0);
    }

    #[test]
    fn never_fit_bucket_rejected_not_requeued() {
        let (cfg, backend, adm, big_store, met) = setup();
        // Pool (4 x 64 = 256 rows) smaller than the 512 bucket: the request
        // must be rejected at admission, not requeued forever, and must not
        // block the servable request behind it.
        let store = PagedKvStore::new(4, 64, big_store.head_dim);
        let bad_rx = submit(&adm, 1, 512);
        let ok_rx = submit(&adm, 2, 128);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(4);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, bad) = final_of(&bad_rx);
        assert!(!bad.ok);
        assert_eq!(bad.outcome, Outcome::Rejected(RejectReason::OverCapacity));
        assert!(bad.error.unwrap().contains("exceeds kv pool capacity"));
        assert!(final_of(&ok_rx).1.ok);
        assert_eq!(met.snapshot().completed, 1);
        assert_eq!(met.snapshot().failed, 1);
    }

    #[test]
    fn decode_footprint_counts_against_pool_capacity() {
        let (cfg, backend, adm, big_store, met) = setup();
        // Pool of exactly 256 rows: a 256-row prompt fits alone, but the
        // same prompt + 10 decode tokens can never fit and must be rejected
        // at admission (the reservation covers prompt + max_new).
        let store = PagedKvStore::new(4, 64, big_store.head_dim);
        let bad_rx = submit_gen(&adm, 1, 256, 10);
        let ok_rx = submit_gen(&adm, 2, 256, 0);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(5);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, bad) = final_of(&bad_rx);
        assert!(!bad.ok);
        assert!(bad.error.unwrap().contains("new tokens exceeds kv pool capacity"));
        assert!(final_of(&ok_rx).1.ok);
    }

    #[test]
    fn kv_exhaustion_requeues_and_recovers() {
        let (cfg, backend, adm, big_store, met) = setup();
        // Pool that fits exactly one 1024-bucket request at a time.
        let store = PagedKvStore::new(16, 64, big_store.head_dim);
        let rxs: Vec<_> = (0..3).map(|i| submit(&adm, i, 1024)).collect();
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(3);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        for rx in rxs {
            assert!(final_of(&rx).1.ok, "requeued requests complete eventually");
        }
        let snap = met.snapshot();
        assert_eq!(snap.completed, 3);
        assert!(snap.kv_rejections > 0, "backpressure must have engaged");
        assert!(snap.requeue_rounds > 0, "requeues are counted");
    }

    #[test]
    fn generation_streams_frames_then_final_response() {
        let (cfg, backend, adm, store, met) = setup();
        let rx = submit_gen(&adm, 1, 128, 5);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(6);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (frames, resp) = final_of(&rx);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.outcome, Outcome::Done);
        assert_eq!(frames, 5, "one streamed frame per generated token");
        assert_eq!(resp.tokens.len(), 5);
        assert_eq!(resp.decode_us.len(), 5);
        assert_eq!(store.used(), 0, "prompt + decode reservation freed");
        let snap = met.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.tokens_generated, 5);
        assert_eq!(snap.early_stopped, 0);
    }

    #[test]
    fn max_new_tokens_clamped_to_cap() {
        let (mut cfg, backend, adm, store, met) = setup();
        cfg.max_new_cap = 3;
        let rx = submit_gen(&adm, 1, 128, 100);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(7);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (frames, resp) = final_of(&rx);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 3, "clamped to max_new_cap");
        assert_eq!(frames, 3);
    }

    #[test]
    fn repeated_prefix_skips_prefill_and_counts_hits() {
        let (cfg, backend, adm, store, met) = setup();
        // Cold request: same seed replayed later under a different id.
        let cold_rx = {
            let (tx, rx) = mpsc::channel();
            let req = PrefillRequest::synthetic(1, 256, 77, AttentionMode::Sparse);
            adm.push(WorkItem::new(req, tx)).unwrap();
            rx
        };
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(10);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, cold) = final_of(&cold_rx);
        assert!(cold.ok, "{:?}", cold.error);
        assert_eq!(cold.chunks, 2, "256 rows at chunk 128");
        assert_eq!(cold.cached_rows, 0);
        assert_eq!(store.used(), 0, "cached blocks are idle capacity, not usage");
        assert!(store.cached_idle() > 0, "completed prompt stays resident");

        let warm_rx = {
            let (tx, rx) = mpsc::channel();
            let req = PrefillRequest::synthetic(2, 256, 77, AttentionMode::Sparse);
            adm.push(WorkItem::new(req, tx)).unwrap();
            rx
        };
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, warm) = final_of(&warm_rx);
        assert!(warm.ok, "{:?}", warm.error);
        assert_eq!(warm.cached_rows, 256, "whole prompt served from the cache");
        assert_eq!(warm.chunks, 1, "one bookkeeping round instead of two compute chunks");
        assert_eq!(warm.output_digest, cold.output_digest, "digest identical to the cold run");
        assert_eq!(warm.density, cold.density, "density identical to the cold run");
        let snap = met.snapshot();
        assert_eq!(snap.prefix_hits, 1);
        assert_eq!(snap.prefix_blocks_shared, 4, "256 rows at 64-row blocks");
        store.assert_consistent();

        // A different prompt shares nothing.
        let other_rx = submit(&adm, 3, 256);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, other) = final_of(&other_rx);
        assert!(other.ok);
        assert_eq!(other.cached_rows, 0);
        assert_eq!(met.snapshot().prefix_hits, 1, "no spurious hits");
    }

    #[test]
    fn prefix_cache_off_means_no_sharing() {
        let (mut cfg, backend, adm, store, met) = setup();
        cfg.prefix_cache = false;
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(12);
        for id in [1u64, 2] {
            let (tx, rx) = mpsc::channel();
            let req = PrefillRequest::synthetic(id, 256, 99, AttentionMode::Sparse);
            adm.push(WorkItem::new(req, tx)).unwrap();
            run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
            let (_, resp) = final_of(&rx);
            assert!(resp.ok);
            assert_eq!(resp.cached_rows, 0);
            assert_eq!(resp.chunks, 2, "full prefill both times");
        }
        let snap = met.snapshot();
        assert_eq!(snap.prefix_hits, 0);
        assert_eq!(store.cached_idle(), 0, "nothing published with the cache off");
    }

    #[test]
    fn stop_token_ends_generation_early_and_reclaims_kv() {
        let (cfg, backend, adm, store, met) = setup();
        // Learn the deterministic token stream first, then replay the same
        // request with its second token as the stop token.
        let probe_rx = submit_gen(&adm, 1, 128, 6);
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(9);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, probe) = final_of(&probe_rx);
        assert!(probe.ok, "{:?}", probe.error);
        assert_eq!(probe.tokens.len(), 6);

        let (tx, rx) = mpsc::channel();
        let mut req = PrefillRequest::synthetic(2, 128, 1, AttentionMode::Sparse);
        req.max_new_tokens = 6;
        req.stop_token = Some(probe.tokens[1]);
        adm.push(WorkItem::new(req, tx)).unwrap();
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (frames, resp) = final_of(&rx);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.outcome, Outcome::Stopped);
        assert_eq!(resp.tokens.len(), 2, "generation stops at the stop token");
        assert_eq!(resp.tokens, probe.tokens[..2], "stop token itself is emitted");
        assert_eq!(frames, 2);
        assert_eq!(store.used(), 0, "early-stopped reservation fully reclaimed");
        assert_eq!(met.snapshot().early_stopped, 1);
    }

    #[test]
    fn cancel_mid_prefill_frees_the_reservation_for_new_work() {
        let (mut cfg, backend, adm, big_store, met) = setup();
        // Prefix cache OFF so the cancelled run leaves nothing resident:
        // the follow-up admission must succeed purely because the
        // reservation was freed, not because blocks went idle-cached.
        cfg.prefix_cache = false;
        // Pool of exactly 1024 rows: one 1024-bucket request fills it.
        let store = PagedKvStore::new(16, 64, big_store.head_dim);
        let caps = backend.capabilities();
        let (tx, rx) = mpsc::channel();
        let req = PrefillRequest::synthetic(1, 1024, 5, AttentionMode::Sparse);
        let flag = req.cancel.clone();
        adm.push(WorkItem::new(req, tx)).unwrap();
        let mut ready = VecDeque::new();
        let mut decoding = DecodeLane::default();
        let mut st = AdmitState::default();
        let mut rng = Rng::new(11);
        admit(&cfg, &backend, &caps, &adm, &store, &met, &mut ready, 0, &mut st, &mut rng);
        assert_eq!(ready.len(), 1);
        assert!(store.used() > 0, "reservation holds the whole pool");
        dispatch_round(&cfg, &backend, &caps, &store, &met, &mut ready, &mut decoding);
        assert_eq!(ready.len(), 1, "1024 rows at chunk 128: still prefilling");
        flag.cancel();
        reap(&store, &met, &mut ready, &mut decoding);
        assert!(ready.is_empty());
        assert_eq!(store.used(), 0, "freed at reap, before the next admission round");
        let (_, resp) = final_of(&rx);
        assert!(!resp.ok);
        assert_eq!(resp.outcome, Outcome::Cancelled);
        // The freed pool admits the next full-size request with no eviction.
        let rx2 = submit(&adm, 2, 1024);
        admit(&cfg, &backend, &caps, &adm, &store, &met, &mut ready, 0, &mut st, &mut rng);
        assert_eq!(ready.len(), 1, "freed blocks place the new reservation immediately");
        while !ready.is_empty() {
            dispatch_round(&cfg, &backend, &caps, &store, &met, &mut ready, &mut decoding);
        }
        let (_, r2) = final_of(&rx2);
        assert!(r2.ok, "{:?}", r2.error);
        assert_eq!(store.used(), 0);
        store.assert_consistent();
        let snap = met.snapshot();
        assert_eq!(snap.cancelled, 1);
        assert_eq!(snap.prefix_evictions, 0, "no eviction was needed");
    }

    #[test]
    fn cancelled_in_decode_is_reaped_with_tokens_so_far() {
        let (cfg, backend, adm, store, met) = setup();
        let caps = backend.capabilities();
        let rx = submit_gen(&adm, 1, 128, 50);
        let mut ready = VecDeque::new();
        let mut decoding = DecodeLane::default();
        let mut st = AdmitState::default();
        let mut rng = Rng::new(15);
        admit(&cfg, &backend, &caps, &adm, &store, &met, &mut ready, 0, &mut st, &mut rng);
        while !ready.is_empty() {
            dispatch_round(&cfg, &backend, &caps, &store, &met, &mut ready, &mut decoding);
        }
        assert_eq!(decoding.len(), 1, "prefill done, decode phase entered");
        decode_round(&backend, &store, &met, &mut decoding);
        assert_eq!(decoding.len(), 1, "50-token budget: still decoding after one step");
        decoding.runs[0].request().cancel.cancel();
        reap(&store, &met, &mut ready, &mut decoding);
        assert!(decoding.is_empty());
        let (frames, resp) = final_of(&rx);
        assert_eq!(frames, 1, "the token generated before cancellation was streamed");
        assert!(!resp.ok);
        assert_eq!(resp.outcome, Outcome::Cancelled);
        assert_eq!(resp.tokens.len(), 1, "partial generation rides in the terminal response");
        assert_eq!(store.used(), 0);
        store.assert_consistent();
        assert_eq!(met.snapshot().cancelled, 1);
    }

    #[test]
    fn deadline_expiry_reaps_a_running_request() {
        let (mut cfg, backend, adm, big_store, met) = setup();
        cfg.prefix_cache = false;
        let store = PagedKvStore::new(16, 64, big_store.head_dim);
        let caps = backend.capabilities();
        let (tx, rx) = mpsc::channel();
        let mut req = PrefillRequest::synthetic(1, 1024, 3, AttentionMode::Sparse);
        req.deadline_ms = Some(200);
        adm.push(WorkItem::new(req, tx)).unwrap();
        let mut ready = VecDeque::new();
        let mut decoding = DecodeLane::default();
        let mut st = AdmitState::default();
        let mut rng = Rng::new(16);
        admit(&cfg, &backend, &caps, &adm, &store, &met, &mut ready, 0, &mut st, &mut rng);
        assert_eq!(ready.len(), 1, "the deadline has not passed at admission");
        dispatch_round(&cfg, &backend, &caps, &store, &met, &mut ready, &mut decoding);
        assert_eq!(ready.len(), 1, "still prefilling");
        // Sleeping past the deadline guarantees expiry (no upper-bound race:
        // the request only needs the deadline to HAVE passed).
        std::thread::sleep(Duration::from_millis(250));
        reap(&store, &met, &mut ready, &mut decoding);
        assert!(ready.is_empty());
        assert_eq!(store.used(), 0, "expired reservation freed at reap");
        let (_, resp) = final_of(&rx);
        assert!(!resp.ok);
        assert_eq!(resp.outcome, Outcome::Expired);
        assert!(resp.error.unwrap().contains("deadline"));
        assert_eq!(met.snapshot().deadline_expired, 1);
        store.assert_consistent();
    }

    #[test]
    fn expired_in_queue_is_rejected_as_deadline_infeasible() {
        let (cfg, backend, adm, store, met) = setup();
        let (tx, rx) = mpsc::channel();
        let mut req = PrefillRequest::synthetic(1, 128, 1, AttentionMode::Sparse);
        req.deadline_ms = Some(0); // expired the instant it was submitted
        adm.push(WorkItem::new(req, tx)).unwrap();
        let stop = AtomicBool::new(true);
        let mut rng = Rng::new(17);
        run_loop(&cfg, &backend, &adm, &store, &met, &stop, &mut rng);
        let (_, resp) = final_of(&rx);
        assert!(!resp.ok);
        assert_eq!(resp.outcome, Outcome::Rejected(RejectReason::DeadlineInfeasible));
        assert!(resp.error.unwrap().contains("deadline"));
        assert_eq!(store.used(), 0, "nothing was ever reserved");
        assert_eq!(met.snapshot().failed, 1);
    }

    #[test]
    fn interactive_requests_admit_ahead_of_batch() {
        let (cfg, backend, adm, store, met) = setup();
        let caps = backend.capabilities();
        let (tx1, _rx1) = mpsc::channel();
        let mut batch = PrefillRequest::synthetic(1, 128, 1, AttentionMode::Sparse);
        batch.priority = Priority::Batch;
        adm.push(WorkItem::new(batch, tx1)).unwrap();
        let (tx2, _rx2) = mpsc::channel();
        let inter = PrefillRequest::synthetic(2, 128, 2, AttentionMode::Sparse);
        adm.push(WorkItem::new(inter, tx2)).unwrap();
        let mut ready = VecDeque::new();
        let mut st = AdmitState::default();
        let mut rng = Rng::new(14);
        admit(&cfg, &backend, &caps, &adm, &store, &met, &mut ready, 0, &mut st, &mut rng);
        assert_eq!(ready.len(), 2);
        assert_eq!(ready[0].run.id(), 2, "interactive admitted ahead of batch");
        assert_eq!(ready[1].run.id(), 1);
    }

    #[test]
    fn concurrent_identical_prompts_defer_behind_the_leader() {
        let (cfg, backend, adm, store, met) = setup();
        let caps = backend.capabilities();
        let mk = |id: u64| {
            let (tx, rx) = mpsc::channel();
            let req = PrefillRequest::synthetic(id, 256, 55, AttentionMode::Sparse);
            adm.push(WorkItem::new(req, tx)).unwrap();
            rx
        };
        let leader_rx = mk(1);
        let follower_rx = mk(2);
        let mut ready = VecDeque::new();
        let mut decoding = DecodeLane::default();
        let mut st = AdmitState::default();
        let mut rng = Rng::new(13);
        admit(&cfg, &backend, &caps, &adm, &store, &met, &mut ready, 0, &mut st, &mut rng);
        assert_eq!(ready.len(), 1, "only the leader admits cold");
        assert_eq!(adm.len(), 1, "the identical follower waits for the leader's blocks");
        // Leader runs chunk 1 of 2 (publishing its first groups); the
        // follower stays deferred because the prompt is only half resident.
        dispatch_round(&cfg, &backend, &caps, &store, &met, &mut ready, &mut decoding);
        admit(&cfg, &backend, &caps, &adm, &store, &met, &mut ready, 0, &mut st, &mut rng);
        assert_eq!(ready.len(), 1, "half-resident prompt: follower still deferred");
        assert_eq!(adm.len(), 1);
        // Chunk 2 completes the leader (freed, fully published).
        dispatch_round(&cfg, &backend, &caps, &store, &met, &mut ready, &mut decoding);
        assert!(ready.is_empty());
        assert!(final_of(&leader_rx).1.ok);
        // Now the follower admits with a FULL prefix hit — one cold prefill
        // total across both identical prompts.
        admit(&cfg, &backend, &caps, &adm, &store, &met, &mut ready, 0, &mut st, &mut rng);
        assert_eq!(adm.len(), 0);
        while !ready.is_empty() {
            dispatch_round(&cfg, &backend, &caps, &store, &met, &mut ready, &mut decoding);
        }
        let (_, follower) = final_of(&follower_rx);
        assert!(follower.ok, "{:?}", follower.error);
        assert_eq!(follower.cached_rows, 256, "entire prompt served from the leader's blocks");
        assert_eq!(follower.chunks, 1, "one bookkeeping round, zero compute chunks");
        assert_eq!(met.snapshot().prefix_hits, 1);
        store.assert_consistent();
    }
}
