//! The coordinator's paged KV store — a re-export of `tensor::paged`.
//!
//! The store itself lives in the tensor layer so the attention kernels
//! (`flash_attention_paged`, `sparse_attention_vs_paged`) can read through
//! `PagedKv` views without depending upward on the serving stack; the
//! coordinator keeps this module as its canonical name for the store
//! (admission reserves, chunks append, completion frees).

pub use crate::tensor::paged::{
    PagedKv, PagedKvStore, PrefixAux, PrefixChain, PrefixGroup, ReserveOutcome,
};
