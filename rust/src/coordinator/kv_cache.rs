//! Paged KV-cache block allocator (vLLM-style accounting).
//!
//! The prefill service reserves `ceil(n / block_size)` blocks per in-flight
//! request; allocation failure backpressures the batcher.  Tracking is by
//! request id; a real decode path would hand these blocks to the KV reader,
//! here they bound prefill concurrency exactly the way a real pool would.

use std::collections::BTreeMap;

pub struct KvCache {
    pub total_blocks: usize,
    pub block_size: usize,
    free: Vec<usize>,
    held: BTreeMap<u64, Vec<usize>>,
    /// High-water mark of allocated blocks (observability).
    pub peak_used: usize,
}

impl KvCache {
    pub fn new(total_blocks: usize, block_size: usize) -> KvCache {
        KvCache {
            total_blocks,
            block_size,
            free: (0..total_blocks).rev().collect(),
            held: BTreeMap::new(),
            peak_used: 0,
        }
    }

    pub fn blocks_for(&self, seq_len: usize) -> usize {
        seq_len.div_ceil(self.block_size)
    }

    pub fn used(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Allocate `count` blocks for a request; all-or-nothing.
    pub fn allocate(&mut self, req_id: u64, count: usize) -> bool {
        if self.free.len() < count || self.held.contains_key(&req_id) {
            return false;
        }
        let blocks: Vec<usize> = (0..count).map(|_| self.free.pop().unwrap()).collect();
        self.held.insert(req_id, blocks);
        self.peak_used = self.peak_used.max(self.used());
        true
    }

    pub fn free(&mut self, req_id: u64) {
        if let Some(blocks) = self.held.remove(&req_id) {
            self.free.extend(blocks);
        }
    }

    pub fn holds(&self, req_id: u64) -> bool {
        self.held.contains_key(&req_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_lifecycle() {
        let mut kv = KvCache::new(10, 64);
        assert_eq!(kv.blocks_for(100), 2);
        assert_eq!(kv.blocks_for(64), 1);
        assert!(kv.allocate(1, 4));
        assert!(kv.holds(1));
        assert_eq!(kv.used(), 4);
        assert!(kv.allocate(2, 6));
        assert!(!kv.allocate(3, 1), "pool exhausted");
        kv.free(1);
        assert!(kv.allocate(3, 3));
        assert_eq!(kv.peak_used, 10);
    }

    #[test]
    fn all_or_nothing() {
        let mut kv = KvCache::new(4, 64);
        assert!(!kv.allocate(1, 5));
        assert_eq!(kv.used(), 0);
    }

    #[test]
    fn double_allocate_same_id_rejected() {
        let mut kv = KvCache::new(8, 64);
        assert!(kv.allocate(1, 2));
        assert!(!kv.allocate(1, 2));
        kv.free(1);
        assert!(kv.allocate(1, 2));
    }

    #[test]
    fn free_unknown_id_is_noop() {
        let mut kv = KvCache::new(4, 64);
        kv.free(99);
        assert_eq!(kv.used(), 0);
    }

    #[test]
    fn blocks_returned_exactly_once() {
        let mut kv = KvCache::new(6, 64);
        assert!(kv.allocate(1, 3));
        assert!(kv.allocate(2, 3));
        kv.free(1);
        kv.free(1); // double free is a no-op
        assert_eq!(kv.used(), 3);
        assert!(kv.allocate(3, 3));
        assert!(!kv.allocate(4, 1));
    }
}
