//! TCP JSON-lines front end.
//!
//! Wire protocol (one JSON object per line):
//!   request:  {"id": 1, "n": 256, "seed": 7, "mode": "sparse", "budget": 0.5,
//!              "chunk": 256, "max_new_tokens": 16, "stop_token": 1234,
//!              "deadline_ms": 500, "priority": "batch"}
//!             or {"id": 1, "tokens": [..], "mode": "dense"}
//!             or {"op": "stats"} for a live service-health snapshot
//!   ("chunk" optionally overrides the coordinator's prefill chunk size;
//!    "max_new_tokens" requests token generation after prefill;
//!    "stop_token" ends generation early when that token is produced;
//!    "deadline_ms" expires the request that many ms after submission;
//!    "priority" is "interactive" (default) or "batch" — batch is shed
//!    first under load)
//!   stream:   zero or more {"frame": "token", "id": .., "index": ..,
//!             "pos": .., "token": .., "itl_us": ..} lines, written as each
//!             decode step completes (TokenFrame::to_json)
//!   response: PrefillResponse::to_json (always the final line; carries the
//!             full token list + per-token ITL, plus the typed "outcome" —
//!             shed/rejected submissions answer with outcome "rejected",
//!             a "reject_reason" and a "retry_after_ms" backoff hint)
//! The connection handler blocks per request (one request's stream at a
//! time per connection); multiple connections are served concurrently, all
//! funneling into the coordinator's admission queue.  A client that stops
//! reading mid-stream (broken pipe on a frame write) is treated as having
//! cancelled: the handler raises the request's cancel flag so the
//! scheduler reaps the run and frees its KV reservation instead of
//! decoding into a closed socket.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::util::json::Json;

use super::admission::Rejected;
use super::engine::AttentionMode;
use super::request::{
    Outcome, PrefillRequest, PrefillResponse, Priority, ResponseEvent, ResponseHandle, TokenFrame,
};
use super::router::ReplicaRouter;
use super::Coordinator;

/// What a [`Server`] serves: one coordinator, or a replica fleet behind
/// the prefix-affinity router.  The wire protocol is identical either way;
/// only the `{"op": "stats"}` answer differs (a fleet reports per-replica
/// health).
pub enum Engine {
    Single(Arc<Coordinator>),
    Fleet(Arc<ReplicaRouter>),
}

impl Engine {
    fn submit(&self, req: PrefillRequest) -> Result<ResponseHandle, Rejected> {
        match self {
            Engine::Single(c) => c.submit(req),
            Engine::Fleet(f) => f.submit(req),
        }
    }

    fn stats(&self) -> Json {
        match self {
            Engine::Single(c) => stats_json(c),
            Engine::Fleet(f) => f.stats_json(),
        }
    }
}

pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

pub fn parse_request(line: &str) -> anyhow::Result<PrefillRequest> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    let id = j.req("id")?.as_f64().unwrap_or(0.0) as u64;
    let mode = match j.get("mode").and_then(|m| m.as_str()).unwrap_or("sparse") {
        "dense" => AttentionMode::Dense,
        _ => AttentionMode::Sparse,
    };
    let mut req = if let Some(tokens) = j.get("tokens") {
        let toks: Vec<i32> = tokens
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tokens must be an array"))?
            .iter()
            .map(|t| t.as_f64().unwrap_or(0.0) as i32)
            .collect();
        PrefillRequest::tokens(id, toks, mode)
    } else {
        let n = j.req("n")?.as_usize().ok_or_else(|| anyhow::anyhow!("n must be a number"))?;
        let seed = j.get("seed").and_then(|s| s.as_f64()).unwrap_or(0.0) as u64;
        PrefillRequest::synthetic(id, n, seed, mode)
    };
    if let Some(b) = j.get("budget").and_then(|b| b.as_f64()) {
        req.budget = b as f32;
    }
    if let Some(c) = j.get("chunk").and_then(|c| c.as_usize()) {
        anyhow::ensure!(c > 0, "chunk must be positive");
        req.chunk = Some(c);
    }
    if let Some(m) = j.get("max_new_tokens").and_then(|m| m.as_usize()) {
        req.max_new_tokens = m;
    }
    if let Some(t) = j.get("stop_token").and_then(|t| t.as_f64()) {
        req.stop_token = Some(t as u32);
    }
    if let Some(d) = j.get("deadline_ms").and_then(|d| d.as_f64()) {
        req.deadline_ms = Some(d as u64);
    }
    if let Some(p) = j.get("priority").and_then(|p| p.as_str()) {
        req.priority = Priority::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown priority {p:?} (interactive|batch)"))?;
    }
    Ok(req)
}

impl Server {
    /// Bind and serve one coordinator on 127.0.0.1:`port` (0 = ephemeral).
    pub fn start(coordinator: Arc<Coordinator>, port: u16) -> anyhow::Result<Server> {
        Server::start_engine(Engine::Single(coordinator), port)
    }

    /// Bind and serve a replica fleet on 127.0.0.1:`port` (0 = ephemeral).
    pub fn start_fleet(router: Arc<ReplicaRouter>, port: u16) -> anyhow::Result<Server> {
        Server::start_engine(Engine::Fleet(router), port)
    }

    fn start_engine(engine: Engine, port: u16) -> anyhow::Result<Server> {
        let engine = Arc::new(engine);
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let c = engine.clone();
                        let s = stop2.clone();
                        conns.push(std::thread::spawn(move || handle_conn(stream, c, s)));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(Server { addr, stop, handle: Some(handle) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, engine: Arc<Engine>, stop: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    // Read timeout so the handler can observe shutdown instead of blocking
    // forever on an idle client.
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(100)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // read_line appends; on timeout we keep the partial prefix and retry.
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) if line.ends_with('\n') => {}
            Ok(_) => continue, // partial line before timeout window closed
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
        let current = std::mem::take(&mut line);
        if current.trim().is_empty() {
            continue;
        }
        let line = current;
        if let Ok(j) = Json::parse(&line) {
            if j.get("op").and_then(|o| o.as_str()) == Some("stats") {
                if writeln!(writer, "{}", engine.stats().to_string()).is_err() {
                    break;
                }
                continue;
            }
        }
        let resp_json = match parse_request(&line) {
            Ok(req) => match engine.submit(req) {
                // Stream the request's events: token frames as they land,
                // then the final response line.
                Ok(handle) => loop {
                    match handle.next_event() {
                        Ok(ResponseEvent::Token(frame)) => {
                            if writeln!(writer, "{}", frame.to_json().to_string()).is_err() {
                                // The client stopped reading mid-stream.
                                // Treat the broken pipe as a cancellation:
                                // raise the flag so the scheduler reaps the
                                // run and frees its KV reservation, and
                                // drain the channel to the terminal event
                                // so the reply sender is never wedged.
                                handle.cancel();
                                while let Ok(ev) = handle.next_event() {
                                    if matches!(ev, ResponseEvent::Done(_)) {
                                        break;
                                    }
                                }
                                return;
                            }
                        }
                        Ok(ResponseEvent::Done(resp)) => break resp.to_json(),
                        Err(_) => break error_json(0, "coordinator stopped mid-request"),
                    }
                },
                // Typed load shedding on the wire: the rejection carries the
                // reason and a retry hint, so clients can back off instead
                // of hammering a saturated queue.
                Err(rej) => PrefillResponse {
                    id: rej.item.req.id,
                    ok: false,
                    outcome: Outcome::Rejected(rej.reason),
                    retry_after_ms: Some(rej.retry_after_ms),
                    error: Some(rej.to_string()),
                    ..Default::default()
                }
                .to_json(),
            },
            Err(e) => error_json(0, &format!("bad request from {peer:?}: {e:#}")),
        };
        if writeln!(writer, "{}", resp_json.to_string()).is_err() {
            break;
        }
    }
}

/// Live service health: the metrics snapshot plus the paged-pool and
/// prefix-cache gauges only the KV store can report.  Served for
/// `{"op": "stats"}` and by `vsprefill info --port`.
pub fn stats_json(coordinator: &Coordinator) -> Json {
    let snap = coordinator.metrics.snapshot();
    let hit_ratio = if snap.completed == 0 {
        0.0
    } else {
        snap.prefix_hits as f64 / snap.completed as f64
    };
    let mut j = snap.to_json();
    if let Json::Obj(m) = &mut j {
        let kv = &coordinator.kv;
        m.insert("kv_used_blocks".to_string(), Json::Num(kv.used() as f64));
        m.insert("kv_peak_used_blocks".to_string(), Json::Num(kv.peak_used() as f64));
        m.insert("kv_cached_idle_blocks".to_string(), Json::Num(kv.cached_idle() as f64));
        m.insert("kv_prefix_entries".to_string(), Json::Num(kv.prefix_entries() as f64));
        m.insert("prefix_hit_ratio".to_string(), Json::Num(hit_ratio));
    }
    j
}

fn error_json(id: u64, msg: &str) -> Json {
    PrefillResponse {
        id,
        ok: false,
        error: Some(msg.to_string()),
        ..Default::default()
    }
    .to_json()
}

/// Blocking client for tests, examples and the load generator.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: stream })
    }

    pub fn prefill_synthetic(
        &mut self,
        id: u64,
        n: usize,
        seed: u64,
        mode: &str,
        budget: f32,
    ) -> anyhow::Result<PrefillResponse> {
        let (frames, resp) = self.generate(id, n, seed, mode, budget, 0)?;
        debug_assert!(frames.is_empty(), "prefill-only request must not stream frames");
        Ok(resp)
    }

    /// Submit a request with a token budget and read the full stream: the
    /// token frames in generation order, then the final response.
    pub fn generate(
        &mut self,
        id: u64,
        n: usize,
        seed: u64,
        mode: &str,
        budget: f32,
        max_new_tokens: usize,
    ) -> anyhow::Result<(Vec<TokenFrame>, PrefillResponse)> {
        let req = Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("n", Json::Num(n as f64)),
            ("seed", Json::Num(seed as f64)),
            ("mode", Json::s(mode)),
            ("budget", Json::Num(budget as f64)),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
        ]);
        writeln!(self.writer, "{}", req.to_string())?;
        let mut frames = Vec::new();
        loop {
            let mut line = String::new();
            let read = self.reader.read_line(&mut line)?;
            anyhow::ensure!(read > 0, "connection closed mid-stream");
            let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
            if j.get("frame").is_some() {
                frames.push(TokenFrame::from_json(&j)?);
            } else {
                return Ok((frames, PrefillResponse::from_json(&j)?));
            }
        }
    }

    /// Fetch the live service-health snapshot (`{"op": "stats"}`).
    pub fn stats(&mut self) -> anyhow::Result<Json> {
        writeln!(self.writer, "{}", Json::obj(vec![("op", Json::s("stats"))]).to_string())?;
        let mut line = String::new();
        let read = self.reader.read_line(&mut line)?;
        anyhow::ensure!(read > 0, "connection closed before stats reply");
        Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_variants() {
        let r = parse_request(r#"{"id": 3, "n": 256, "seed": 9, "mode": "dense"}"#).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.seq_len(), 256);
        assert_eq!(r.mode, AttentionMode::Dense);

        let r2 = parse_request(r#"{"id": 4, "tokens": [1,2,3], "budget": 0.25}"#).unwrap();
        assert_eq!(r2.seq_len(), 3);
        assert_eq!(r2.mode, AttentionMode::Sparse);
        assert!((r2.budget - 0.25).abs() < 1e-6);
        assert_eq!(r2.chunk, None);

        let r3 = parse_request(r#"{"id": 5, "n": 512, "chunk": 128}"#).unwrap();
        assert_eq!(r3.chunk, Some(128));
        assert!(parse_request(r#"{"id": 6, "n": 512, "chunk": 0}"#).is_err());

        let r4 = parse_request(r#"{"id": 7, "n": 256, "max_new_tokens": 16, "stop_token": 99}"#)
            .unwrap();
        assert_eq!(r4.max_new_tokens, 16);
        assert_eq!(r4.stop_token, Some(99));
        assert_eq!(r3.max_new_tokens, 0, "absent field defaults to prefill-only");
        assert_eq!(r3.stop_token, None);
        assert_eq!(r4.deadline_ms, None, "absent deadline means none");
        assert_eq!(r4.priority, Priority::Interactive, "default priority");

        let r5 =
            parse_request(r#"{"id": 8, "n": 128, "deadline_ms": 500, "priority": "batch"}"#)
                .unwrap();
        assert_eq!(r5.deadline_ms, Some(500));
        assert_eq!(r5.priority, Priority::Batch);
        assert!(parse_request(r#"{"id": 9, "n": 128, "priority": "bogus"}"#).is_err());

        assert!(parse_request("{}").is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn tcp_round_trip() {
        use crate::coordinator::CoordinatorConfig;
        use crate::serve::EngineBuilder;
        let cfg = CoordinatorConfig { max_wait_ms: 1, ..Default::default() };
        let coordinator = Arc::new(EngineBuilder::new().config(cfg).build().unwrap());
        let server = Server::start(coordinator.clone(), 0).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        let resp = client.prefill_synthetic(7, 128, 1, "sparse", 0.5).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.id, 7);
        assert!(resp.density < 1.0);
        // second request on the same connection
        let resp2 = client.prefill_synthetic(8, 128, 1, "dense", 0.5).unwrap();
        assert!(resp2.ok);
        assert_eq!(resp2.density, 1.0);
        server.shutdown();
    }

    #[test]
    fn generation_streams_frames_over_tcp() {
        use crate::coordinator::CoordinatorConfig;
        use crate::serve::EngineBuilder;
        let cfg = CoordinatorConfig { max_wait_ms: 1, ..Default::default() };
        let coordinator = Arc::new(EngineBuilder::new().config(cfg).build().unwrap());
        let server = Server::start(coordinator.clone(), 0).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        let (frames, resp) = client.generate(9, 128, 2, "sparse", 0.5, 5).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(frames.len(), 5, "one frame line per generated token");
        assert_eq!(resp.tokens.len(), 5);
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.id, 9);
            assert_eq!(f.index, i);
            assert_eq!(f.pos, resp.bucket + i, "token K/V rows extend the prompt");
            assert_eq!(f.token, resp.tokens[i], "frames and final response agree");
        }
        assert_eq!(
            frames.iter().map(|f| f.itl_us).collect::<Vec<_>>(),
            resp.decode_us,
            "per-token ITL matches between stream and final response"
        );
        server.shutdown();
    }

    #[test]
    fn stats_op_reports_service_health_over_the_wire() {
        use crate::coordinator::CoordinatorConfig;
        use crate::serve::EngineBuilder;
        let cfg = CoordinatorConfig { max_wait_ms: 1, ..Default::default() };
        let coordinator = Arc::new(EngineBuilder::new().config(cfg).build().unwrap());
        let server = Server::start(coordinator.clone(), 0).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        // Two identical prompts: the second is a warm prefix-cache hit.
        assert!(client.prefill_synthetic(1, 256, 42, "sparse", 0.5).unwrap().ok);
        assert!(client.prefill_synthetic(2, 256, 42, "sparse", 0.5).unwrap().ok);
        let s = client.stats().unwrap();
        let num = |k: &str| s.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
        assert_eq!(num("completed"), 2.0);
        assert_eq!(num("prefix_hits"), 1.0);
        assert!((num("prefix_hit_ratio") - 0.5).abs() < 1e-9);
        assert_eq!(num("kv_used_blocks"), 0.0, "both requests drained");
        assert!(num("kv_cached_idle_blocks") > 0.0, "warm blocks linger idle");
        assert!(num("kv_prefix_entries") > 0.0);
        // Overload counters ride along in the same snapshot.
        assert_eq!(num("shed_requests"), 0.0);
        assert_eq!(num("deadline_expired"), 0.0);
        assert_eq!(num("cancelled"), 0.0);
        // Adaptive-pattern telemetry rides along too: with the adaptive
        // knobs off, every sparse request lowers as vertical-slash, and
        // the per-head density bins record the two completions.
        assert_eq!(num("pattern_vs"), 2.0);
        assert_eq!(num("pattern_ashape"), 0.0);
        assert_eq!(num("pattern_block"), 0.0);
        let heads = s.get("density_by_head").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(heads.len(), 8);
        let touched: f64 = heads.iter().filter_map(|h| h.as_f64()).sum();
        assert!(touched > 0.0, "sparse completions land in a head bin");
        // A normal request still works on the same connection afterwards.
        assert!(client.prefill_synthetic(3, 128, 7, "sparse", 0.5).unwrap().ok);
        server.shutdown();
    }

    #[test]
    fn fleet_stats_flow_over_the_wire() {
        use crate::coordinator::CoordinatorConfig;
        use crate::serve::EngineBuilder;
        let cfg = CoordinatorConfig { max_wait_ms: 1, replicas: 2, ..Default::default() };
        let fleet = Arc::new(EngineBuilder::new().config(cfg).build_fleet().unwrap());
        let server = Server::start_fleet(fleet, 0).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        // The same prompt twice: the router must send the repeat to the
        // warm replica, where it scores a prefix hit.
        assert!(client.prefill_synthetic(1, 256, 42, "sparse", 0.5).unwrap().ok);
        assert!(client.prefill_synthetic(2, 256, 42, "sparse", 0.5).unwrap().ok);
        let s = client.stats().unwrap();
        let num = |k: &str| s.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
        assert_eq!(num("replicas"), 2.0);
        assert_eq!(num("routed_affinity") + num("routed_load"), 2.0);
        assert!(num("routed_affinity") >= 1.0, "the repeat followed its warm prefix");
        let fleet_arr = s.get("fleet").and_then(|f| f.as_arr()).unwrap();
        assert_eq!(fleet_arr.len(), 2);
        let per = |k: &str| -> Vec<f64> {
            fleet_arr.iter().map(|r| r.get(k).and_then(|x| x.as_f64()).unwrap()).collect()
        };
        assert_eq!(per("completed").iter().sum::<f64>(), 2.0);
        assert_eq!(per("prefix_hits").iter().sum::<f64>(), 1.0);
        assert!(per("kv_cached_idle_blocks").iter().sum::<f64>() > 0.0, "warm pool visible");
        server.shutdown();
    }

    #[test]
    fn wire_rejection_is_typed_with_a_retry_hint() {
        use crate::coordinator::request::RejectReason;
        use crate::coordinator::CoordinatorConfig;
        use crate::serve::EngineBuilder;
        // A full-sized pool but a tiny queue with batch shedding at depth 1:
        // batch requests racing in over many connections get typed shed
        // responses once the queue backs up.
        let cfg = CoordinatorConfig {
            max_wait_ms: 1,
            max_queue: 2,
            shed_queue_depth: 1,
            ..Default::default()
        };
        let coordinator = Arc::new(EngineBuilder::new().config(cfg).build().unwrap());
        let server = Server::start(coordinator.clone(), 0).unwrap();
        let addr = server.addr;
        let workers: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let req = Json::obj(vec![
                        ("id", Json::Num(100.0 + i as f64)),
                        ("n", Json::Num(1024.0)),
                        ("seed", Json::Num(i as f64)),
                        ("priority", Json::s("batch")),
                    ]);
                    writeln!(client.writer, "{}", req.to_string()).unwrap();
                    let mut line = String::new();
                    client.reader.read_line(&mut line).unwrap();
                    PrefillResponse::from_json(&Json::parse(&line).unwrap()).unwrap()
                })
            })
            .collect();
        let resps: Vec<PrefillResponse> = workers.into_iter().map(|w| w.join().unwrap()).collect();
        let shed: Vec<_> = resps
            .iter()
            .filter(|r| r.outcome == Outcome::Rejected(RejectReason::Shed))
            .collect();
        assert!(resps.iter().any(|r| r.ok), "some requests still complete");
        if let Some(r) = shed.first() {
            assert!(!r.ok);
            assert!(r.retry_after_ms.is_some(), "shed responses carry a backoff hint");
            assert!(r.error.as_deref().unwrap_or("").contains("shed"));
        }
        server.shutdown();
    }
}
