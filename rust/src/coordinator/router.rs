//! Prefix-affinity replica router — level 2 of the scale-out topology.
//!
//! Level 1 ([`super::backend::sharded::ShardedBackend`]) splits one prefill
//! chunk across N backend shards; this level spreads *independent requests*
//! across M full engine stacks (coordinator + executor + paged KV pool).
//! Placement is **affinity-then-load**:
//!
//! 1. Compute the request's [`PrefixChain`] once on the router's probe
//!    backend and ask every replica's paged pool how much of it is already
//!    resident ([`PagedKvStore::probe_prefix`] — a hash-index lookup, no
//!    lock on the executor).  The replica with the most resident rows wins;
//!    a chain currently being prefilled by an in-flight leader counts as
//!    fully resident, so followers herd onto the leader's replica and
//!    coalesce there instead of recomputing the prefix cold elsewhere.
//! 2. If no replica holds any of the prefix (or the backend opts out of
//!    chains), fall back to the replica with the shortest admission queue
//!    (lowest index on ties).
//!
//! Each placement increments the chosen replica's `routed_affinity` or
//! `routed_load` counter, so `{"op": "stats"}` and `vsprefill info` can
//! show whether the fleet is actually getting warm-prefix locality or just
//! load-balancing.  Rejections stay typed: a routed submission that hits a
//! full queue hands back the usual [`admission::Rejected`] with its retry
//! hint — the router does not silently retry elsewhere, because the chosen
//! replica was already the best (warmest or least-loaded) home for it.

use std::sync::atomic::Ordering;
use std::sync::Mutex;

use crate::tensor::paged::PrefixChain;
use crate::util::json::Json;

use super::admission::Rejected;
use super::backend::{Capabilities, ExecBackend};
use super::metrics::Snapshot;
use super::request::{PrefillRequest, PrefillResponse, ResponseHandle};
use super::{server, Coordinator};

/// A fleet of coordinator replicas behind one prefix-affinity placement
/// policy.  Build through [`crate::serve::EngineBuilder::build_fleet`].
pub struct ReplicaRouter {
    replicas: Vec<Coordinator>,
    /// The router's own backend instance, used only for request -> chain
    /// mapping (never for execution).  `ExecBackend` is `Send` but not
    /// `Sync`, so the router serializes its probe calls behind a mutex;
    /// chain hashing is cheap relative to any prefill.
    probe: Mutex<Box<dyn ExecBackend>>,
}

impl ReplicaRouter {
    pub fn new(
        replicas: Vec<Coordinator>,
        probe: Box<dyn ExecBackend>,
    ) -> anyhow::Result<ReplicaRouter> {
        anyhow::ensure!(!replicas.is_empty(), "a replica fleet needs at least one coordinator");
        Ok(ReplicaRouter { replicas, probe: Mutex::new(probe) })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    pub fn replicas(&self) -> &[Coordinator] {
        &self.replicas
    }

    /// The fleet's capability surface: the probe backend's, with the
    /// replica dimension set to the fleet width.
    pub fn capabilities(&self) -> Capabilities {
        let mut caps = self.probe.lock().expect("probe backend poisoned").capabilities();
        caps.replicas = self.replicas.len();
        caps
    }

    /// The request's prefix chain as the probe backend sees it (all
    /// replicas share one configuration, so one chain fits every pool).
    fn chain_for(&self, req: &PrefillRequest) -> Option<PrefixChain> {
        let probe = self.probe.lock().expect("probe backend poisoned");
        let block_size = self.replicas[0].kv.block_size;
        probe.bucket_for(req.seq_len()).and_then(|b| probe.prefix_chain(req, b, block_size))
    }

    /// Choose a replica for `req` and count the placement on it:
    /// warmest-prefix first, least-loaded fallback.
    pub fn route(&self, req: &PrefillRequest) -> usize {
        if let Some(chain) = self.chain_for(req) {
            let mut best: Option<(usize, usize)> = None; // (score, replica)
            for (i, r) in self.replicas.iter().enumerate() {
                let p = r.kv.probe_prefix(&chain);
                // An in-flight leader scores as a full chain: followers are
                // herded to the leader's replica, where the scheduler's
                // coalescing turns them into a shared-prefix hit.
                let score = p.resident_rows + if p.inflight { chain.rows() } else { 0 };
                if score > 0 && best.is_none_or(|(s, _)| score > s) {
                    best = Some((score, i));
                }
            }
            if let Some((_, i)) = best {
                self.replicas[i].metrics.routed_affinity.fetch_add(1, Ordering::Relaxed);
                return i;
            }
        }
        let i = (0..self.replicas.len())
            .min_by_key(|&i| self.replicas[i].queue_len())
            .unwrap_or(0);
        self.replicas[i].metrics.routed_load.fetch_add(1, Ordering::Relaxed);
        i
    }

    /// Route and submit; the handle streams from the chosen replica.
    pub fn submit(&self, req: PrefillRequest) -> Result<ResponseHandle, Rejected> {
        let i = self.route(&req);
        self.replicas[i].submit(req)
    }

    /// Route, submit, and block for the final response.
    pub fn prefill(&self, req: PrefillRequest) -> anyhow::Result<PrefillResponse> {
        let i = self.route(&req);
        self.replicas[i].prefill(req)
    }

    /// Fleet health for the wire and `vsprefill info`: totals of the
    /// routing counters plus every replica's full stats object (each with
    /// its own pool gauges), in replica order.
    pub fn stats_json(&self) -> Json {
        let mut affinity = 0u64;
        let mut load = 0u64;
        let mut fleet = Vec::new();
        for r in &self.replicas {
            affinity += r.metrics.routed_affinity.load(Ordering::Relaxed);
            load += r.metrics.routed_load.load(Ordering::Relaxed);
            fleet.push(server::stats_json(r));
        }
        Json::obj(vec![
            ("replicas", Json::Num(self.replicas.len() as f64)),
            ("routed_affinity", Json::Num(affinity as f64)),
            ("routed_load", Json::Num(load as f64)),
            ("fleet", Json::Arr(fleet)),
        ])
    }

    /// Stop every replica and return their final snapshots, replica order.
    pub fn shutdown(self) -> Vec<Snapshot> {
        self.replicas.into_iter().map(|r| r.shutdown()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::native::NativeBackend;
    use crate::coordinator::{AttentionMode, CoordinatorConfig};

    fn fleet(m: usize) -> ReplicaRouter {
        let cfg = CoordinatorConfig { max_wait_ms: 1, ..Default::default() };
        let replicas = (0..m)
            .map(|_| {
                let backend = Box::new(NativeBackend::quick(cfg.engine.clone()));
                Coordinator::start(cfg.clone(), backend)
            })
            .collect();
        ReplicaRouter::new(replicas, Box::new(NativeBackend::quick(cfg.engine.clone()))).unwrap()
    }

    #[test]
    fn cold_requests_take_the_least_loaded_door() {
        let router = fleet(2);
        // Distinct prompts: nothing is warm anywhere, every placement is a
        // load-balance decision.
        for i in 0..4 {
            let r = router
                .prefill(PrefillRequest::synthetic(i, 128, 100 + i, AttentionMode::Sparse))
                .unwrap();
            assert!(r.ok, "{:?}", r.error);
        }
        let (mut affinity, mut load) = (0, 0);
        for r in router.replicas() {
            affinity += r.metrics.routed_affinity.load(Ordering::Relaxed);
            load += r.metrics.routed_load.load(Ordering::Relaxed);
        }
        assert_eq!(affinity, 0, "distinct prompts never score affinity");
        assert_eq!(load, 4, "every placement is counted exactly once");
    }

    #[test]
    fn warm_prefix_wins_over_load_balance() {
        let router = fleet(2);
        // Cold run of one prompt lands somewhere and leaves its prefix
        // resident there.
        let cold =
            router.prefill(PrefillRequest::synthetic(1, 256, 42, AttentionMode::Sparse)).unwrap();
        assert!(cold.ok);
        let home = router
            .replicas()
            .iter()
            .position(|r| r.metrics.completed.load(Ordering::Relaxed) == 1)
            .expect("the cold run completed on some replica");
        // The repeat must follow the warm prefix home, not round-robin away.
        let warm =
            router.prefill(PrefillRequest::synthetic(2, 256, 42, AttentionMode::Sparse)).unwrap();
        assert!(warm.ok);
        let r = &router.replicas()[home];
        assert_eq!(r.metrics.completed.load(Ordering::Relaxed), 2, "repeat landed on home");
        assert_eq!(r.metrics.routed_affinity.load(Ordering::Relaxed), 1);
        assert_eq!(r.metrics.prefix_hits.load(Ordering::Relaxed), 1, "and hit the warm blocks");
    }

    #[test]
    fn fleet_stats_report_per_replica_health() {
        let router = fleet(2);
        assert!(router
            .prefill(PrefillRequest::synthetic(1, 128, 7, AttentionMode::Sparse))
            .unwrap()
            .ok);
        let caps = router.capabilities();
        assert_eq!(caps.replicas, 2);
        let j = Json::parse(&router.stats_json().to_string()).unwrap();
        assert_eq!(j.get("replicas").and_then(|x| x.as_f64()), Some(2.0));
        assert_eq!(
            j.get("routed_affinity").and_then(|x| x.as_f64()).unwrap()
                + j.get("routed_load").and_then(|x| x.as_f64()).unwrap(),
            1.0,
            "one placement, counted once, visible in the fleet totals"
        );
        let fleet = j.get("fleet").and_then(|f| f.as_arr()).unwrap();
        assert_eq!(fleet.len(), 2);
        for replica in fleet {
            assert!(replica.get("kv_used_blocks").is_some(), "pool gauges per replica");
            assert!(replica.get("completed").is_some());
        }
        let done: f64 = fleet
            .iter()
            .map(|r| r.get("completed").and_then(|x| x.as_f64()).unwrap())
            .sum();
        assert_eq!(done, 1.0);
    }

    #[test]
    fn empty_fleet_is_rejected() {
        let cfg = CoordinatorConfig::default();
        let probe = Box::new(NativeBackend::quick(cfg.engine.clone()));
        assert!(ReplicaRouter::new(Vec::new(), probe).is_err());
    }
}
