//! Service metrics: lock-free counters + mutex-guarded latency reservoirs.
//!
//! The latency/density streams are recorded into fixed-capacity sampling
//! reservoirs (`util::stats::Reservoir`, Algorithm R), so a long-running
//! server's metrics memory is bounded no matter how many requests or tokens
//! it serves; percentiles over the reservoir estimate the full stream's.
//! Snapshots serialize to JSON with non-finite values guarded (the JSON
//! writer renders them as null), so NaN/Inf can never corrupt the wire.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::{mean, percentile_sorted, Reservoir};

use super::request::{Outcome, PrefillResponse};

/// Samples kept per latency stream — bounded memory for unbounded uptime.
const RESERVOIR_CAP: usize = 4096;

pub struct Metrics {
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub kv_rejections: AtomicU64,
    /// Total prefill chunks executed across completed requests.
    pub chunks_executed: AtomicU64,
    /// Total tokens generated across completed requests.
    pub tokens_generated: AtomicU64,
    /// Generations ended by a stop token before `max_new_tokens` (their
    /// unused KV tail blocks were reclaimed early).
    pub early_stopped: AtomicU64,
    /// Requests admitted with at least one prompt block served from the
    /// shared-prefix KV cache.
    pub prefix_hits: AtomicU64,
    /// Total cached blocks pinned (shared, not recomputed) across all
    /// admissions.
    pub prefix_blocks_shared: AtomicU64,
    /// Idle cached blocks evicted (LRU) to make room for reservations.
    pub prefix_evictions: AtomicU64,
    /// `Batch`-priority requests refused at admission to protect
    /// interactive traffic (reject reason `shed`).
    pub shed_requests: AtomicU64,
    /// Admitted requests reaped because their deadline passed.
    pub deadline_expired: AtomicU64,
    /// Requests cancelled by the client (explicitly or by disconnect).
    pub cancelled: AtomicU64,
    /// Scheduler rounds that failed to place any queued work (KV pool
    /// full) and backed off before retrying.
    pub requeue_rounds: AtomicU64,
    /// Requests the replica router placed on this coordinator because its
    /// paged pool already held (or was prefilling) the request's prefix.
    pub routed_affinity: AtomicU64,
    /// Requests the replica router placed here by least-loaded fallback
    /// (no replica held the prefix).
    pub routed_load: AtomicU64,
    /// Pattern-choice histogram of the adaptive classifier: completed
    /// sparse requests whose head lowered as vertical-slash / A-shape /
    /// block-sparse.
    pub pattern_vs: AtomicU64,
    pub pattern_ashape: AtomicU64,
    pub pattern_block: AtomicU64,
    /// Per-head density accumulators, binned by the response's head bin
    /// (0..8): (density sum, count) per bin.
    head_density: Mutex<[(f64, u64); 8]>,
    prefill_us: Mutex<Reservoir>,
    queue_us: Mutex<Reservoir>,
    index_us: Mutex<Reservoir>,
    ttft_us: Mutex<Reservoir>,
    /// Per-token inter-token latencies (one sample per generated token).
    itl_us: Mutex<Reservoir>,
    densities: Mutex<Reservoir>,
}

#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub completed: u64,
    pub failed: u64,
    pub kv_rejections: u64,
    pub chunks_executed: u64,
    pub tokens_generated: u64,
    pub early_stopped: u64,
    pub prefix_hits: u64,
    pub prefix_blocks_shared: u64,
    pub prefix_evictions: u64,
    pub shed_requests: u64,
    pub deadline_expired: u64,
    pub cancelled: u64,
    pub requeue_rounds: u64,
    pub routed_affinity: u64,
    pub routed_load: u64,
    pub p50_prefill_us: f64,
    pub p95_prefill_us: f64,
    pub p50_ttft_us: f64,
    pub p95_ttft_us: f64,
    /// Inter-token latency percentiles across all generated tokens.
    pub p50_itl_us: f64,
    pub p95_itl_us: f64,
    /// Mean time per output token (the mean ITL) — the TPOT headline.
    pub mean_tpot_us: f64,
    pub mean_queue_us: f64,
    pub mean_index_us: f64,
    pub mean_density: f64,
    /// Adaptive pattern-choice histogram across completed sparse requests.
    pub pattern_vs: u64,
    pub pattern_ashape: u64,
    pub pattern_block: u64,
    /// Mean mask density per head bin (0..8); 0.0 for bins with no traffic.
    pub density_by_head: Vec<f64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        let res = || Mutex::new(Reservoir::new(RESERVOIR_CAP));
        Metrics {
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            kv_rejections: AtomicU64::new(0),
            chunks_executed: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            early_stopped: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            prefix_blocks_shared: AtomicU64::new(0),
            prefix_evictions: AtomicU64::new(0),
            shed_requests: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            requeue_rounds: AtomicU64::new(0),
            routed_affinity: AtomicU64::new(0),
            routed_load: AtomicU64::new(0),
            pattern_vs: AtomicU64::new(0),
            pattern_ashape: AtomicU64::new(0),
            pattern_block: AtomicU64::new(0),
            head_density: Mutex::new([(0.0, 0); 8]),
            prefill_us: res(),
            queue_us: res(),
            index_us: res(),
            ttft_us: res(),
            itl_us: res(),
            densities: res(),
        }
    }

    pub fn record(&self, resp: &PrefillResponse) {
        if resp.ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
            self.chunks_executed.fetch_add(resp.chunks, Ordering::Relaxed);
            self.tokens_generated.fetch_add(resp.tokens.len() as u64, Ordering::Relaxed);
            self.prefill_us.lock().expect("reservoir poisoned").push(resp.prefill_us as f64);
            self.queue_us.lock().expect("reservoir poisoned").push(resp.queue_us as f64);
            self.index_us.lock().expect("reservoir poisoned").push(resp.index_us as f64);
            self.ttft_us.lock().expect("reservoir poisoned").push(resp.ttft_us as f64);
            self.densities.lock().expect("reservoir poisoned").push(resp.density);
            match resp.pattern.as_deref() {
                Some("vs") => self.pattern_vs.fetch_add(1, Ordering::Relaxed),
                Some("ashape") => self.pattern_ashape.fetch_add(1, Ordering::Relaxed),
                Some("block") => self.pattern_block.fetch_add(1, Ordering::Relaxed),
                _ => 0,
            };
            let mut hd = self.head_density.lock().expect("head-density poisoned");
            let bin = &mut hd[resp.head.min(7)];
            bin.0 += resp.density;
            bin.1 += 1;
            drop(hd);
            let mut itl = self.itl_us.lock().expect("reservoir poisoned");
            for &us in &resp.decode_us {
                itl.push(us as f64);
            }
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        match resp.outcome {
            Outcome::Stopped => self.early_stopped.fetch_add(1, Ordering::Relaxed),
            Outcome::Expired => self.deadline_expired.fetch_add(1, Ordering::Relaxed),
            Outcome::Cancelled => self.cancelled.fetch_add(1, Ordering::Relaxed),
            _ => 0,
        };
    }

    pub fn snapshot(&self) -> Snapshot {
        let sorted = |r: &Mutex<Reservoir>| {
            let mut v = r.lock().expect("reservoir poisoned").values().to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        };
        let prefill = sorted(&self.prefill_us);
        let ttft = sorted(&self.ttft_us);
        let itl = sorted(&self.itl_us);
        let queue = self.queue_us.lock().expect("reservoir poisoned").values().to_vec();
        let index = self.index_us.lock().expect("reservoir poisoned").values().to_vec();
        let dens = self.densities.lock().expect("reservoir poisoned").values().to_vec();
        let density_by_head = self
            .head_density
            .lock()
            .expect("head-density poisoned")
            .iter()
            .map(|&(sum, count)| if count > 0 { sum / count as f64 } else { 0.0 })
            .collect();
        Snapshot {
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            kv_rejections: self.kv_rejections.load(Ordering::Relaxed),
            chunks_executed: self.chunks_executed.load(Ordering::Relaxed),
            tokens_generated: self.tokens_generated.load(Ordering::Relaxed),
            early_stopped: self.early_stopped.load(Ordering::Relaxed),
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_blocks_shared: self.prefix_blocks_shared.load(Ordering::Relaxed),
            prefix_evictions: self.prefix_evictions.load(Ordering::Relaxed),
            shed_requests: self.shed_requests.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            requeue_rounds: self.requeue_rounds.load(Ordering::Relaxed),
            routed_affinity: self.routed_affinity.load(Ordering::Relaxed),
            routed_load: self.routed_load.load(Ordering::Relaxed),
            p50_prefill_us: percentile_sorted(&prefill, 0.5),
            p95_prefill_us: percentile_sorted(&prefill, 0.95),
            p50_ttft_us: percentile_sorted(&ttft, 0.5),
            p95_ttft_us: percentile_sorted(&ttft, 0.95),
            p50_itl_us: percentile_sorted(&itl, 0.5),
            p95_itl_us: percentile_sorted(&itl, 0.95),
            mean_tpot_us: mean(&itl),
            mean_queue_us: mean(&queue),
            mean_index_us: mean(&index),
            mean_density: mean(&dens),
            pattern_vs: self.pattern_vs.load(Ordering::Relaxed),
            pattern_ashape: self.pattern_ashape.load(Ordering::Relaxed),
            pattern_block: self.pattern_block.load(Ordering::Relaxed),
            density_by_head,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Snapshot {
    /// Wire form of the snapshot.  Counters are exact; latency fields are
    /// reservoir estimates.  Non-finite values are impossible by
    /// construction (the reservoirs reject them and empty percentiles are
    /// 0), and the JSON writer additionally renders any non-finite number
    /// as null — belt and braces for the wire format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("kv_rejections", Json::Num(self.kv_rejections as f64)),
            ("chunks_executed", Json::Num(self.chunks_executed as f64)),
            ("tokens_generated", Json::Num(self.tokens_generated as f64)),
            ("early_stopped", Json::Num(self.early_stopped as f64)),
            ("prefix_hits", Json::Num(self.prefix_hits as f64)),
            ("prefix_blocks_shared", Json::Num(self.prefix_blocks_shared as f64)),
            ("prefix_evictions", Json::Num(self.prefix_evictions as f64)),
            ("shed_requests", Json::Num(self.shed_requests as f64)),
            ("deadline_expired", Json::Num(self.deadline_expired as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("requeue_rounds", Json::Num(self.requeue_rounds as f64)),
            ("routed_affinity", Json::Num(self.routed_affinity as f64)),
            ("routed_load", Json::Num(self.routed_load as f64)),
            ("p50_prefill_us", Json::Num(self.p50_prefill_us)),
            ("p95_prefill_us", Json::Num(self.p95_prefill_us)),
            ("p50_ttft_us", Json::Num(self.p50_ttft_us)),
            ("p95_ttft_us", Json::Num(self.p95_ttft_us)),
            ("p50_itl_us", Json::Num(self.p50_itl_us)),
            ("p95_itl_us", Json::Num(self.p95_itl_us)),
            ("mean_tpot_us", Json::Num(self.mean_tpot_us)),
            ("mean_queue_us", Json::Num(self.mean_queue_us)),
            ("mean_index_us", Json::Num(self.mean_index_us)),
            ("mean_density", Json::Num(self.mean_density)),
            ("pattern_vs", Json::Num(self.pattern_vs as f64)),
            ("pattern_ashape", Json::Num(self.pattern_ashape as f64)),
            ("pattern_block", Json::Num(self.pattern_block as f64)),
            ("density_by_head", Json::arr_f64(&self.density_by_head)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(ok: bool, prefill_us: u64, density: f64) -> PrefillResponse {
        PrefillResponse {
            ok,
            prefill_us,
            density,
            chunks: 2,
            ttft_us: prefill_us / 2,
            ..Default::default()
        }
    }

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=10 {
            m.record(&resp(true, i * 100, 0.2));
        }
        m.record(&resp(false, 0, 0.0));
        let s = m.snapshot();
        assert_eq!(s.completed, 10);
        assert_eq!(s.failed, 1);
        assert_eq!(s.chunks_executed, 20);
        assert!((s.p50_prefill_us - 550.0).abs() < 1.0);
        assert!((s.p50_ttft_us - 275.0).abs() < 1.0);
        assert!(s.p95_ttft_us >= s.p50_ttft_us);
        assert!((s.mean_density - 0.2).abs() < 1e-9);
    }

    #[test]
    fn records_token_streams_and_itl() {
        let m = Metrics::new();
        let mut r = resp(true, 500, 0.3);
        r.tokens = vec![1, 2, 3, 4];
        r.decode_us = vec![100, 200, 300, 400];
        m.record(&r);
        let s = m.snapshot();
        assert_eq!(s.tokens_generated, 4);
        assert!((s.p50_itl_us - 250.0).abs() < 1.0);
        assert!(s.p95_itl_us >= s.p50_itl_us);
        assert!((s.mean_tpot_us - 250.0).abs() < 1e-9);
    }

    #[test]
    fn reservoirs_bound_memory_under_load() {
        // Far more requests than the reservoir capacity: snapshots stay
        // sane and the per-stream sample count is capped.
        let m = Metrics::new();
        for i in 0..(2 * 4096u64) {
            let mut r = resp(true, 100 + i % 500, 0.2);
            r.decode_us = vec![50 + i % 100];
            r.tokens = vec![1];
            m.record(&r);
        }
        assert_eq!(m.prefill_us.lock().unwrap().len(), 4096);
        assert_eq!(m.itl_us.lock().unwrap().len(), 4096);
        let s = m.snapshot();
        assert_eq!(s.completed, 2 * 4096);
        assert_eq!(s.tokens_generated, 2 * 4096);
        assert!(s.p50_prefill_us >= 100.0 && s.p50_prefill_us <= 600.0);
    }

    #[test]
    fn prefix_counters_reach_snapshot_and_wire() {
        let m = Metrics::new();
        m.prefix_hits.fetch_add(3, Ordering::Relaxed);
        m.prefix_blocks_shared.fetch_add(12, Ordering::Relaxed);
        m.prefix_evictions.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!((s.prefix_hits, s.prefix_blocks_shared, s.prefix_evictions), (3, 12, 2));
        let back = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.get("prefix_hits").and_then(|x| x.as_f64()), Some(3.0));
        assert_eq!(back.get("prefix_blocks_shared").and_then(|x| x.as_f64()), Some(12.0));
        assert_eq!(back.get("prefix_evictions").and_then(|x| x.as_f64()), Some(2.0));
    }

    #[test]
    fn pattern_and_head_density_reach_snapshot_and_wire() {
        let m = Metrics::new();
        let mut r = resp(true, 100, 0.4);
        r.head = 2;
        r.pattern = Some("vs".to_string());
        m.record(&r);
        m.record(&r);
        r.density = 0.2;
        r.head = 5;
        r.pattern = Some("ashape".to_string());
        m.record(&r);
        r.head = 5;
        r.pattern = Some("block".to_string());
        m.record(&r);
        // Failed responses and dense ones (no pattern) leave the histogram
        // and the head bins alone.
        let mut bad = resp(false, 0, 0.0);
        bad.pattern = Some("vs".to_string());
        m.record(&bad);
        m.record(&resp(true, 100, 1.0));
        let s = m.snapshot();
        assert_eq!((s.pattern_vs, s.pattern_ashape, s.pattern_block), (2, 1, 1));
        assert_eq!(s.density_by_head.len(), 8);
        assert!((s.density_by_head[2] - 0.4).abs() < 1e-9);
        assert!((s.density_by_head[5] - 0.2).abs() < 1e-9);
        assert_eq!(s.density_by_head[7], 0.0, "untouched bin stays zero");
        let back = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.get("pattern_vs").and_then(|x| x.as_f64()), Some(2.0));
        assert_eq!(back.get("pattern_ashape").and_then(|x| x.as_f64()), Some(1.0));
        assert_eq!(back.get("pattern_block").and_then(|x| x.as_f64()), Some(1.0));
        let heads = back.get("density_by_head").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(heads.len(), 8);
        assert!((heads[2].as_f64().unwrap() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn typed_outcomes_feed_the_overload_counters() {
        let m = Metrics::new();
        let mut r = resp(false, 0, 0.0);
        r.outcome = Outcome::Expired;
        m.record(&r);
        r.outcome = Outcome::Cancelled;
        m.record(&r);
        m.record(&r);
        let mut stopped = resp(true, 100, 0.2);
        stopped.outcome = Outcome::Stopped;
        m.record(&stopped);
        m.shed_requests.fetch_add(4, Ordering::Relaxed);
        m.requeue_rounds.fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.early_stopped, 1);
        assert_eq!(s.failed, 3, "expired/cancelled also count as not-ok");
        assert_eq!(s.completed, 1, "stopped is a success door");
        let back = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.get("shed_requests").and_then(|x| x.as_f64()), Some(4.0));
        assert_eq!(back.get("deadline_expired").and_then(|x| x.as_f64()), Some(1.0));
        assert_eq!(back.get("cancelled").and_then(|x| x.as_f64()), Some(2.0));
        assert_eq!(back.get("requeue_rounds").and_then(|x| x.as_f64()), Some(5.0));
    }

    #[test]
    fn router_counters_reach_snapshot_and_wire() {
        let m = Metrics::new();
        m.routed_affinity.fetch_add(7, Ordering::Relaxed);
        m.routed_load.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!((s.routed_affinity, s.routed_load), (7, 2));
        let back = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.get("routed_affinity").and_then(|x| x.as_f64()), Some(7.0));
        assert_eq!(back.get("routed_load").and_then(|x| x.as_f64()), Some(2.0));
    }

    #[test]
    fn empty_snapshot_serializes_finite_json() {
        // No samples recorded: every field must serialize to parseable JSON
        // with zeros, never NaN.
        let s = Metrics::new().snapshot();
        let text = s.to_json().to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("p50_itl_us").and_then(|x| x.as_f64()), Some(0.0));
        assert_eq!(back.get("mean_tpot_us").and_then(|x| x.as_f64()), Some(0.0));
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
    }
}
