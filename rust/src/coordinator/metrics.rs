//! Service metrics: lock-free counters + a mutex-guarded latency reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::{percentile_sorted, summarize};

use super::request::PrefillResponse;

pub struct Metrics {
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub kv_rejections: AtomicU64,
    /// Total prefill chunks executed across completed requests.
    pub chunks_executed: AtomicU64,
    prefill_us: Mutex<Vec<f64>>,
    queue_us: Mutex<Vec<f64>>,
    index_us: Mutex<Vec<f64>>,
    ttft_us: Mutex<Vec<f64>>,
    densities: Mutex<Vec<f64>>,
}

#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub completed: u64,
    pub failed: u64,
    pub kv_rejections: u64,
    pub chunks_executed: u64,
    pub p50_prefill_us: f64,
    pub p95_prefill_us: f64,
    pub p50_ttft_us: f64,
    pub p95_ttft_us: f64,
    pub mean_queue_us: f64,
    pub mean_index_us: f64,
    pub mean_density: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            kv_rejections: AtomicU64::new(0),
            chunks_executed: AtomicU64::new(0),
            prefill_us: Mutex::new(Vec::new()),
            queue_us: Mutex::new(Vec::new()),
            index_us: Mutex::new(Vec::new()),
            ttft_us: Mutex::new(Vec::new()),
            densities: Mutex::new(Vec::new()),
        }
    }

    pub fn record(&self, resp: &PrefillResponse) {
        if resp.ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
            self.chunks_executed.fetch_add(resp.chunks, Ordering::Relaxed);
            self.prefill_us.lock().unwrap().push(resp.prefill_us as f64);
            self.queue_us.lock().unwrap().push(resp.queue_us as f64);
            self.index_us.lock().unwrap().push(resp.index_us as f64);
            self.ttft_us.lock().unwrap().push(resp.ttft_us as f64);
            self.densities.lock().unwrap().push(resp.density);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut prefill = self.prefill_us.lock().unwrap().clone();
        prefill.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut ttft = self.ttft_us.lock().unwrap().clone();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let queue = self.queue_us.lock().unwrap();
        let index = self.index_us.lock().unwrap();
        let dens = self.densities.lock().unwrap();
        let pct = |xs: &[f64], p: f64| if xs.is_empty() { 0.0 } else { percentile_sorted(xs, p) };
        Snapshot {
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            kv_rejections: self.kv_rejections.load(Ordering::Relaxed),
            chunks_executed: self.chunks_executed.load(Ordering::Relaxed),
            p50_prefill_us: pct(&prefill, 0.5),
            p95_prefill_us: pct(&prefill, 0.95),
            p50_ttft_us: pct(&ttft, 0.5),
            p95_ttft_us: pct(&ttft, 0.95),
            mean_queue_us: summarize(&queue).mean,
            mean_index_us: summarize(&index).mean,
            mean_density: summarize(&dens).mean,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(ok: bool, prefill_us: u64, density: f64) -> PrefillResponse {
        PrefillResponse { ok, prefill_us, density, chunks: 2, ttft_us: prefill_us / 2, ..Default::default() }
    }

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=10 {
            m.record(&resp(true, i * 100, 0.2));
        }
        m.record(&resp(false, 0, 0.0));
        let s = m.snapshot();
        assert_eq!(s.completed, 10);
        assert_eq!(s.failed, 1);
        assert_eq!(s.chunks_executed, 20);
        assert!((s.p50_prefill_us - 550.0).abs() < 1.0);
        assert!((s.p50_ttft_us - 275.0).abs() < 1.0);
        assert!(s.p95_ttft_us >= s.p50_ttft_us);
        assert!((s.mean_density - 0.2).abs() < 1e-9);
    }
}
