//! The prefill execution pipeline.
//!
//! Two backends behind one interface:
//!   * `Native` — synthesizes the head (Appendix-A.1 generator), runs the
//!     Rust indexer + budgeter + tiled sparse executor.  No artifacts
//!     needed; used by unit tests and the ablation harness.
//!   * `Pjrt`  — the production path: AOT model prefill / indexer / fused
//!     sparse-attention graphs executed through the PJRT engine, with the
//!     distilled indexer weights fed as graph arguments.
//!
//! Pipeline per request (§4.3): K/V from prefill -> VSIndexer scores ->
//! cumulative-threshold budgets -> top-k indices (+ merge in the executor)
//! -> sparse attention -> output digest.

use std::time::Instant;

use crate::attention::decode::flash_decode_into;
use crate::attention::flash::flash_attention_paged;
use crate::indexer::train::{distill, TrainConfig};
use crate::indexer::{IncrementalScores, Indexer};
#[cfg(feature = "pjrt")]
use crate::runtime;
use crate::sparse_attn::exec::{
    decode_columns, sparse_attention_vs, sparse_attention_vs_paged, sparse_decode_vs_into,
};
use crate::sparse_attn::VsPrefill;
use crate::synth::{gen_head, SynthConfig, SynthHead, SynthStream};
use crate::tensor::paged::PagedKv;
use crate::tensor::Mat;
use crate::util::parallel::par_chunks_mut;
use crate::util::rng::Rng;

use super::kv_cache::PagedKvStore;
use super::request::{Payload, PrefillRequest, PrefillResponse, TokenFrame};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttentionMode {
    Dense,
    Sparse,
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub synth: SynthConfig,
    /// Buckets served (must match artifacts for the PJRT backend).
    pub buckets: Vec<usize>,
    /// Block size of the tiled native executor.
    pub block_q: usize,
    /// Worker-pool size for the execution engine (kernels and the
    /// coordinator's batch fan-out).  0 = auto: `VSPREFILL_THREADS` env var,
    /// else available parallelism.
    pub threads: usize,
    /// Decode budget: vertical columns kept per sparse decode step (top-k
    /// of the request's incrementally-maintained vertical index scores).
    pub decode_top_k: usize,
    /// Decode budget: local window of most recent positions always attended
    /// by a sparse decode step (the slash structure collapsed onto the
    /// single decode row).
    pub decode_window: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            synth: SynthConfig::default(),
            buckets: vec![128, 256, 512, 1024],
            block_q: 64,
            threads: 0,
            decode_top_k: 64,
            decode_window: 64,
        }
    }
}

enum Backend {
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(runtime::Engine),
}

pub struct PrefillEngine {
    pub cfg: EngineConfig,
    vsp: VsPrefill,
    backend: Backend,
    /// Indexer weights for the PJRT indexer graph (loaded from artifacts).
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    pjrt_weights: Option<std::collections::BTreeMap<String, (Vec<usize>, Vec<f32>)>>,
}

impl PrefillEngine {
    /// Native backend with a quickly-distilled indexer (tests, ablations).
    /// The indexer is distilled once per process and cached — distillation
    /// dominates startup otherwise.
    pub fn native_quick(cfg: EngineConfig) -> PrefillEngine {
        static CACHED: std::sync::OnceLock<Indexer> = std::sync::OnceLock::new();
        let ix = CACHED
            .get_or_init(|| {
                let tc = TrainConfig {
                    steps: 150,
                    batch: 3,
                    seq_len: 128,
                    hidden_base: 32,
                    synth: SynthConfig::default(),
                    ..Default::default()
                };
                distill(&tc).0
            })
            .clone();
        PrefillEngine { cfg, vsp: VsPrefill::new(ix), backend: Backend::Native, pjrt_weights: None }
    }

    /// Native backend with a caller-provided indexer.
    pub fn native_with(cfg: EngineConfig, indexer: Indexer) -> PrefillEngine {
        PrefillEngine { cfg, vsp: VsPrefill::new(indexer), backend: Backend::Native, pjrt_weights: None }
    }

    /// PJRT backend: loads artifacts + the Python-distilled indexer weights.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(cfg: EngineConfig, rt: runtime::Engine) -> anyhow::Result<PrefillEngine> {
        let weights = rt.bundle.load_weights("indexer_weights.json")?;
        let text = std::fs::read_to_string(rt.bundle.dir.join("indexer_weights.json"))?;
        let ix = Indexer::load_json(&text)?;
        let buckets = rt.bundle.buckets.clone();
        let mut cfg = cfg;
        cfg.buckets = buckets;
        Ok(PrefillEngine {
            cfg,
            vsp: VsPrefill::new(ix),
            backend: Backend::Pjrt(rt),
            pjrt_weights: Some(weights),
        })
    }

    pub fn buckets(&self) -> Vec<usize> {
        self.cfg.buckets.clone()
    }

    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.cfg.buckets.iter().cloned().filter(|&b| b >= n).min()
    }

    /// True when `process` may be called concurrently from several threads
    /// on a shared reference: the native backend is plain owned data with no
    /// interior mutability, while the PJRT backend holds single-threaded
    /// wrapper types (`Rc`s, raw executable pointers).
    pub fn supports_parallel(&self) -> bool {
        match &self.backend {
            Backend::Native => true,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => false,
        }
    }

    /// Process one request (called from the executor thread, or — for the
    /// native backend — from the coordinator's batch worker pool).
    pub fn process(&self, req: &PrefillRequest, rng: &mut Rng) -> PrefillResponse {
        let queue_us = req.submitted_at.elapsed().as_micros() as u64;
        let mut resp = PrefillResponse { id: req.id, queue_us, ..Default::default() };
        let n = req.seq_len();
        let bucket = match self.bucket_for(n) {
            Some(b) => b,
            None => {
                resp.error = Some(format!("seq_len {n} exceeds largest bucket"));
                return resp;
            }
        };
        resp.bucket = bucket;
        let t0 = Instant::now();
        let result = match &self.backend {
            Backend::Native => self.process_native(req, bucket, rng, &mut resp),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => self.process_pjrt(req, bucket, rng, &mut resp),
        };
        resp.prefill_us = t0.elapsed().as_micros() as u64;
        // Monolithic execution is one chunk: TTFT is the full prefill.
        resp.chunks = 1;
        resp.chunk_us = vec![resp.prefill_us];
        resp.ttft_us = resp.queue_us + resp.prefill_us;
        match result {
            Ok(()) => resp.ok = true,
            Err(e) => resp.error = Some(format!("{e:#}")),
        }
        resp
    }

    /// True when the backend can run the chunked pipeline (paged KV store +
    /// incremental indexing).  The PJRT backend's AOT graphs are
    /// whole-bucket, so it falls back to monolithic execution per request.
    pub fn supports_chunked(&self) -> bool {
        match &self.backend {
            Backend::Native => true,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => false,
        }
    }

    /// Start a chunked prefill: the caller has already resolved `bucket`
    /// (via [`bucket_for`](Self::bucket_for)) and reserved `bucket` rows in
    /// the paged store.  `chunk` is the coordinator's default chunk size;
    /// the request's own `chunk` field overrides it.
    pub fn begin_chunked(
        &self,
        req: PrefillRequest,
        bucket: usize,
        chunk: usize,
        rng: &mut Rng,
    ) -> ChunkRun {
        let queue_us = req.submitted_at.elapsed().as_micros() as u64;
        let resp = PrefillResponse { id: req.id, queue_us, bucket, ..Default::default() };
        let mut run_rng = rng.fork(req.id);
        let (head, stream) = self.synth_parts(&req, bucket, &mut run_rng);
        let chunk = req.chunk.unwrap_or(chunk).clamp(1, bucket);
        ChunkRun {
            req,
            bucket,
            chunk,
            next: 0,
            head,
            stream,
            inc: IncrementalScores::new(),
            rng: run_rng,
            resp,
        }
    }

    /// Execute the next chunk of `run` against the paged store: append the
    /// chunk's K/V rows, update the incremental index scores, and run the
    /// paged attention executor over the chunk's queries.  Returns
    /// `ChunkStep::Done` with the finished response after the last chunk
    /// (the caller frees the store reservation and replies).
    pub fn process_chunk(&self, run: &mut ChunkRun, store: &PagedKvStore) -> ChunkStep {
        if !self.supports_chunked() {
            // Whole-bucket AOT graphs (PJRT): execute monolithically as one
            // chunk.
            return ChunkStep::Done(self.process(&run.req, &mut run.rng));
        }
        let t0 = Instant::now();
        let lo = run.next;
        let hi = (lo + run.chunk).min(run.bucket);
        let kc = run.head.k.sub_rows(lo, hi);
        let vc = run.head.v.sub_rows(lo, hi);
        if let Err(e) = store.append(run.req.id, &kc, &vc) {
            run.resp.error = Some(format!("{e:#}"));
            return ChunkStep::Done(std::mem::take(&mut run.resp));
        }
        let Some(view) = store.view(run.req.id) else {
            run.resp.error = Some(format!("request {} lost its kv reservation", run.req.id));
            return ChunkStep::Done(std::mem::take(&mut run.resp));
        };
        let qc = run.head.q.sub_rows(lo, hi);
        let out = match run.req.mode {
            AttentionMode::Dense => {
                run.resp.density = 1.0;
                flash_attention_paged(&qc, lo, &view, self.cfg.block_q, self.cfg.block_q)
            }
            AttentionMode::Sparse => {
                let ti = Instant::now();
                // Incremental scoring over the newly-arrived rows, then
                // selection over every key resident so far.  On the final
                // chunk the scores equal the monolithic `predict_kv`
                // exactly, so the reported density matches monolithic
                // execution bit-for-bit.
                self.vsp.indexer.score_chunk(&mut run.inc, &kc, &vc);
                let (a_v, a_s) = run.inc.finalize();
                let idx = self.vsp.select_from_scores(&a_v, &a_s, hi, run.req.budget);
                run.resp.index_us += ti.elapsed().as_micros() as u64;
                run.resp.density = idx.density(hi);
                sparse_attention_vs_paged(&qc, lo, &view, &idx, self.cfg.block_q)
            }
        };
        if lo == 0 {
            run.resp.output_digest = digest(&out);
        }
        let dt = t0.elapsed().as_micros() as u64;
        run.resp.chunk_us.push(dt);
        run.resp.prefill_us += dt;
        run.resp.chunks += 1;
        if run.resp.chunks == 1 {
            run.resp.ttft_us = run.req.submitted_at.elapsed().as_micros() as u64;
        }
        run.next = hi;
        if hi >= run.bucket {
            run.resp.ok = true;
            ChunkStep::Done(std::mem::take(&mut run.resp))
        } else {
            ChunkStep::Progress
        }
    }

    /// Synthesize the prompt head plus the decode-phase continuation
    /// stream.  The stream is handed the content RNG in the same freshly
    /// seeded state `gen_head` receives it, so it re-derives the head's
    /// mean vectors and heavy-hitter direction exactly — decode rows come
    /// from the same distribution family as the prompt.
    fn synth_parts(
        &self,
        req: &PrefillRequest,
        bucket: usize,
        rng: &mut Rng,
    ) -> (SynthHead, SynthStream) {
        match &req.payload {
            Payload::Synthetic { seed, .. } => {
                let mut r = Rng::new(*seed);
                let head = gen_head(&mut r, bucket, &self.cfg.synth, seed % 8);
                let stream =
                    SynthStream::continue_head(&self.cfg.synth, Rng::new(*seed), seed % 8, bucket);
                (head, stream)
            }
            Payload::Tokens(toks) => {
                // Derive a deterministic head from the token content so the
                // native path is usable without the model artifact.
                let mut h = 0u64;
                for &t in toks {
                    h = h.wrapping_mul(31).wrapping_add(t as u64);
                }
                let r = rng.fork(h);
                let head = gen_head(&mut r.clone(), bucket, &self.cfg.synth, h % 8);
                let stream = SynthStream::continue_head(&self.cfg.synth, r, h % 8, bucket);
                (head, stream)
            }
        }
    }

    fn head_for(&self, req: &PrefillRequest, bucket: usize, rng: &mut Rng) -> SynthHead {
        self.synth_parts(req, bucket, rng).0
    }

    fn process_native(
        &self,
        req: &PrefillRequest,
        bucket: usize,
        rng: &mut Rng,
        resp: &mut PrefillResponse,
    ) -> anyhow::Result<()> {
        let head = self.head_for(req, bucket, rng);
        let out = match req.mode {
            AttentionMode::Dense => {
                resp.density = 1.0;
                crate::attention::flash::flash_attention(
                    &head.q, &head.k, &head.v, self.cfg.block_q, self.cfg.block_q,
                )
            }
            AttentionMode::Sparse => {
                let ti = Instant::now();
                let idx = self.vsp.predict_kv(&head.k, &head.v, req.budget);
                resp.index_us = ti.elapsed().as_micros() as u64;
                resp.density = idx.density(bucket);
                sparse_attention_vs(&head.q, &head.k, &head.v, &idx, self.cfg.block_q)
            }
        };
        resp.output_digest = digest(&out);
        Ok(())
    }

    #[cfg(feature = "pjrt")]
    fn process_pjrt(
        &self,
        req: &PrefillRequest,
        bucket: usize,
        rng: &mut Rng,
        resp: &mut PrefillResponse,
    ) -> anyhow::Result<()> {
        let Backend::Pjrt(rt) = &self.backend else { unreachable!() };
        let head = self.head_for(req, bucket, rng);
        let out: Mat = match req.mode {
            AttentionMode::Dense => {
                resp.density = 1.0;
                rt.flash_attention(bucket, &head.q, &head.k, &head.v)?
            }
            AttentionMode::Sparse => {
                let ti = Instant::now();
                // Index prediction through the AOT indexer graph.
                let w = self.pjrt_weights.as_ref().unwrap();
                let (a_v, a_s) = rt.indexer_forward(bucket, &head.k, &head.v, w)?;
                let caps = rt
                    .graph(&format!("sparse_attn_{bucket}"))?
                    .caps
                    .unwrap_or((bucket, bucket));
                let capped = VsPrefill {
                    cap_v: Some(caps.0),
                    cap_s: Some(caps.1),
                    ..VsPrefill::new(self.vsp.indexer.clone())
                };
                let idx = capped.select_from_scores(&a_v, &a_s, bucket, req.budget);
                resp.index_us = ti.elapsed().as_micros() as u64;
                resp.density = idx.density(bucket);
                rt.sparse_attention(bucket, &head.q, &head.k, &head.v, &idx)?
            }
        };
        resp.output_digest = digest(&out);
        Ok(())
    }
}

/// In-flight chunked prefill for one request: the synthesized head (the
/// stand-in for the model forward), the incremental index-score state, the
/// cursor into the sequence, and the accumulating response.
pub struct ChunkRun {
    pub req: PrefillRequest,
    /// Bucket the request was padded to (its prompt-row reservation in the
    /// paged store; the full reservation additionally covers
    /// `max_new_tokens` decode rows).
    pub bucket: usize,
    /// Rows per chunk.
    pub chunk: usize,
    /// Next absolute row to process (== rows appended to the store so far).
    pub next: usize,
    head: SynthHead,
    /// Decode-phase continuation of the head (positions >= bucket).
    stream: SynthStream,
    inc: IncrementalScores,
    /// Consumed by the monolithic (non-chunked backend) fallback.
    rng: Rng,
    resp: PrefillResponse,
}

/// Outcome of one `process_chunk` call.
pub enum ChunkStep {
    /// More chunks remain; the run goes back in the ready queue.
    Progress,
    /// The request finished (successfully or with `error` set); the caller
    /// transitions it to decode (if tokens were requested) or frees the KV
    /// reservation and replies.
    Done(PrefillResponse),
}

/// In-flight decode for one request that finished prefill: the synth
/// continuation stream, the carried-over incremental index scores (sparse
/// column selection stays fresh as new K/V rows land), and the accumulating
/// response.
pub struct DecodeState {
    pub req: PrefillRequest,
    /// Prompt rows resident in the paged store (the padded bucket).
    pub bucket: usize,
    /// Tokens generated so far.
    pub generated: usize,
    /// Tokens to generate (already capped at admission; > 0 by
    /// construction — zero-token requests never enter decode).
    pub max_new: usize,
    stream: SynthStream,
    inc: IncrementalScores,
    resp: PrefillResponse,
    /// Wall-clock anchor for inter-token latency (set at the prefill ->
    /// decode transition, advanced every step).
    last_token_at: Instant,
}

/// Outcome of one decode step for one request.
pub enum DecodeStep {
    /// A token was generated; more remain.
    Token(TokenFrame),
    /// The final token was generated; the caller frees the KV reservation
    /// and replies with the finished response.
    Done(TokenFrame, PrefillResponse),
    /// The step failed (store error); the caller frees and replies.
    Failed(PrefillResponse),
}

impl PrefillEngine {
    /// Transition a finished chunked prefill into the decode phase.  The
    /// run's KV reservation stays live (it covers `bucket + max_new` rows);
    /// `resp` is the completed prefill response the decode phase keeps
    /// accumulating tokens and timings into.
    pub fn begin_decode(&self, run: ChunkRun, resp: PrefillResponse) -> DecodeState {
        DecodeState {
            bucket: run.bucket,
            generated: 0,
            max_new: run.req.max_new_tokens,
            stream: run.stream,
            inc: run.inc,
            resp,
            req: run.req,
            last_token_at: Instant::now(),
        }
    }

    /// One batched decode step: every state in `states` generates its next
    /// token.  Phase 1 (serial, cheap) synthesizes each request's next
    /// (q, k, v) row, appends K/V to the paged store and — for sparse
    /// requests — scores the new row into the incremental index state and
    /// selects the step's columns (top-k verticals + local window).  Phase 2
    /// runs the batch's single-query attention fanned across the worker
    /// pool (the batched-decode analog of the prefill chunk fan-out).
    /// Phase 3 (serial) turns outputs into token frames and completion
    /// transitions.  Returns one `DecodeStep` per state, index-aligned.
    pub fn decode_round(&self, states: &mut [DecodeState], store: &PagedKvStore) -> Vec<DecodeStep> {
        let d = self.cfg.synth.head_dim;
        let block_k = self.cfg.block_q.max(1);
        // Phase 1: generate + append + index-score.
        enum Job<'s> {
            Ready { q: Mat, view: PagedKv<'s>, cols: Option<Vec<usize>> },
            Failed,
        }
        let mut jobs: Vec<Job<'_>> = Vec::with_capacity(states.len());
        for st in states.iter_mut() {
            let (q, k, v) = st.stream.next_row();
            if let Err(e) = store.append(st.req.id, &k, &v) {
                st.resp.error = Some(format!("{e:#}"));
                jobs.push(Job::Failed);
                continue;
            }
            let Some(view) = store.view(st.req.id) else {
                st.resp.error =
                    Some(format!("request {} lost its kv reservation mid-decode", st.req.id));
                jobs.push(Job::Failed);
                continue;
            };
            let cols = match st.req.mode {
                AttentionMode::Dense => None,
                AttentionMode::Sparse => {
                    let ti = Instant::now();
                    self.vsp.indexer.score_chunk(&mut st.inc, &k, &v);
                    let a_v = st.inc.finalize_vertical();
                    let c = decode_columns(
                        &a_v,
                        view.len,
                        self.cfg.decode_top_k,
                        self.cfg.decode_window,
                    );
                    st.resp.index_us += ti.elapsed().as_micros() as u64;
                    Some(c)
                }
            };
            jobs.push(Job::Ready { q, view, cols });
        }
        // Phase 2: batched single-query attention across the pool.  The
        // closure captures only the jobs and free-function kernels (not
        // `self`), so it stays Sync regardless of backend.
        let mut out = Mat::zeros(states.len(), d.max(1));
        par_chunks_mut(&mut out.data, d.max(1), |i, chunk| {
            if let Job::Ready { q, view, cols } = &jobs[i] {
                match cols {
                    None => flash_decode_into(q.row(0), view, block_k, chunk),
                    Some(c) => sparse_decode_vs_into(q.row(0), view, c, chunk),
                }
            }
        });
        // Phase 3: tokens, frames, transitions.
        let now = Instant::now();
        let mut steps = Vec::with_capacity(states.len());
        for (i, (st, job)) in states.iter_mut().zip(jobs).enumerate() {
            match job {
                Job::Failed => {
                    let mut resp = std::mem::take(&mut st.resp);
                    resp.ok = false;
                    steps.push(DecodeStep::Failed(resp));
                }
                Job::Ready { .. } => {
                    let token = token_from(out.row(i));
                    let itl = now.duration_since(st.last_token_at).as_micros() as u64;
                    st.last_token_at = now;
                    let frame = TokenFrame {
                        id: st.req.id,
                        index: st.generated,
                        pos: st.bucket + st.generated,
                        token,
                        itl_us: itl,
                    };
                    st.generated += 1;
                    st.resp.tokens.push(token);
                    st.resp.decode_us.push(itl);
                    if st.generated >= st.max_new {
                        let mut resp = std::mem::take(&mut st.resp);
                        resp.ok = resp.error.is_none();
                        steps.push(DecodeStep::Done(frame, resp));
                    } else {
                        steps.push(DecodeStep::Token(frame));
                    }
                }
            }
        }
        steps
    }
}

/// Deterministic synthetic token readout: FNV-1a over the attended output's
/// bits, folded into a 32k vocabulary.  Stands in for the LM head + sampler
/// the toy model does not have — what matters for the serving stack is that
/// tokens are cheap, deterministic, and depend on the attention output.
fn token_from(out: &[f32]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &x in out {
        h = (h ^ x.to_bits()).wrapping_mul(16_777_619);
    }
    h % 32_000
}

fn digest(m: &Mat) -> Vec<f32> {
    m.data.iter().take(4).cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_dense_vs_sparse_digests_close() {
        let e = PrefillEngine::native_quick(EngineConfig::default());
        let mut rng = Rng::new(0);
        let rd = e.process(&PrefillRequest::synthetic(1, 128, 3, AttentionMode::Dense), &mut rng);
        let rs = e.process(&PrefillRequest::synthetic(2, 128, 3, AttentionMode::Sparse), &mut rng);
        assert!(rd.ok && rs.ok);
        assert_eq!(rd.bucket, 128);
        assert!(rs.density < 1.0);
        // Same synthetic head; sparse output should approximate dense.
        for (a, b) in rd.output_digest.iter().zip(&rs.output_digest) {
            assert!((a - b).abs() < 0.35, "{:?} vs {:?}", rd.output_digest, rs.output_digest);
        }
    }

    #[test]
    fn oversized_request_fails_cleanly() {
        let e = PrefillEngine::native_quick(EngineConfig::default());
        let mut rng = Rng::new(0);
        let r = e.process(&PrefillRequest::synthetic(1, 999_999, 0, AttentionMode::Dense), &mut rng);
        assert!(!r.ok);
        assert!(r.error.unwrap().contains("exceeds"));
    }

    #[test]
    fn chunked_dense_matches_monolithic_digest_exactly() {
        let e = PrefillEngine::native_quick(EngineConfig::default());
        let mut rng = Rng::new(0);
        let mono = e.process(&PrefillRequest::synthetic(1, 256, 3, AttentionMode::Dense), &mut rng);
        assert!(mono.ok);
        assert_eq!(mono.chunks, 1);
        let store = PagedKvStore::new(64, 16, e.cfg.synth.head_dim);
        let bucket = e.bucket_for(256).unwrap();
        assert!(store.reserve(2, bucket));
        let req = PrefillRequest::synthetic(2, 256, 3, AttentionMode::Dense);
        let mut run = e.begin_chunked(req, bucket, 100, &mut rng);
        let resp = loop {
            match e.process_chunk(&mut run, &store) {
                ChunkStep::Done(r) => break r,
                ChunkStep::Progress => {}
            }
        };
        store.free(2);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.chunks, 3, "256 rows at chunk 100 -> 3 chunks");
        assert_eq!(resp.chunk_us.len(), 3);
        assert_eq!(resp.output_digest, mono.output_digest, "paged chunked == contiguous");
        assert!(resp.ttft_us > 0 && resp.ttft_us <= resp.queue_us + resp.prefill_us);
    }

    #[test]
    fn chunked_sparse_density_matches_monolithic() {
        let e = PrefillEngine::native_quick(EngineConfig::default());
        let mut rng = Rng::new(0);
        let mono = e.process(&PrefillRequest::synthetic(1, 256, 9, AttentionMode::Sparse), &mut rng);
        assert!(mono.ok);
        let store = PagedKvStore::new(64, 16, e.cfg.synth.head_dim);
        let bucket = e.bucket_for(256).unwrap();
        assert!(store.reserve(2, bucket));
        let req = PrefillRequest::synthetic(2, 256, 9, AttentionMode::Sparse);
        let mut run = e.begin_chunked(req, bucket, 64, &mut rng);
        let resp = loop {
            match e.process_chunk(&mut run, &store) {
                ChunkStep::Done(r) => break r,
                ChunkStep::Progress => {}
            }
        };
        store.free(2);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.chunks, 4);
        // The final chunk's incremental scores equal the monolithic
        // predict_kv exactly, so the selected mask (and density) agree.
        assert_eq!(resp.density, mono.density);
        assert!(resp.index_us > 0);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let e = PrefillEngine::native_quick(EngineConfig::default());
        let mut rng = Rng::new(0);
        let a = e.process(&PrefillRequest::synthetic(1, 128, 9, AttentionMode::Sparse), &mut rng);
        let b = e.process(&PrefillRequest::synthetic(2, 128, 9, AttentionMode::Sparse), &mut rng);
        assert_eq!(a.output_digest, b.output_digest);
        assert_eq!(a.density, b.density);
    }

    /// Drive one request through chunked prefill into decode, returning the
    /// finished response.
    fn prefill_then_decode(
        e: &PrefillEngine,
        store: &PagedKvStore,
        req: PrefillRequest,
        chunk: usize,
    ) -> PrefillResponse {
        let mut rng = Rng::new(0);
        let bucket = e.bucket_for(req.seq_len()).unwrap();
        let max_new = req.max_new_tokens;
        assert!(store.reserve(req.id, bucket + max_new));
        let id = req.id;
        let mut run = e.begin_chunked(req, bucket, chunk, &mut rng);
        let prefill_resp = loop {
            match e.process_chunk(&mut run, store) {
                ChunkStep::Done(r) => break r,
                ChunkStep::Progress => {}
            }
        };
        assert!(prefill_resp.ok, "{:?}", prefill_resp.error);
        let mut states = vec![e.begin_decode(run, prefill_resp)];
        let resp = loop {
            let steps = e.decode_round(&mut states, store);
            match steps.into_iter().next().unwrap() {
                DecodeStep::Token(_) => {}
                DecodeStep::Done(frame, resp) => {
                    assert_eq!(frame.index + 1, max_new);
                    break resp;
                }
                DecodeStep::Failed(resp) => break resp,
            }
        };
        store.free(id);
        resp
    }

    #[test]
    fn decode_generates_requested_tokens_and_appends_kv() {
        let e = PrefillEngine::native_quick(EngineConfig::default());
        let store = PagedKvStore::new(64, 16, e.cfg.synth.head_dim);
        let mut req = PrefillRequest::synthetic(1, 128, 5, AttentionMode::Sparse);
        req.max_new_tokens = 6;
        let resp = prefill_then_decode(&e, &store, req, 64);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 6);
        assert_eq!(resp.decode_us.len(), 6);
        assert!(resp.tokens.iter().all(|&t| t < 32_000));
        assert_eq!(store.used(), 0, "reservation freed after decode");
    }

    #[test]
    fn decode_tokens_deterministic_across_ids() {
        let e = PrefillEngine::native_quick(EngineConfig::default());
        let store = PagedKvStore::new(64, 16, e.cfg.synth.head_dim);
        let mk = |id: u64, mode: AttentionMode| {
            let mut r = PrefillRequest::synthetic(id, 128, 5, mode);
            r.max_new_tokens = 4;
            r
        };
        let a = prefill_then_decode(&e, &store, mk(1, AttentionMode::Sparse), 64);
        let b = prefill_then_decode(&e, &store, mk(2, AttentionMode::Sparse), 64);
        assert_eq!(a.tokens, b.tokens, "same seed => same token stream, id-independent");
        let c = prefill_then_decode(&e, &store, mk(3, AttentionMode::Dense), 64);
        let d = prefill_then_decode(&e, &store, mk(4, AttentionMode::Dense), 64);
        assert_eq!(c.tokens, d.tokens, "dense decode deterministic too");
    }
}
