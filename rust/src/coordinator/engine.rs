//! Engine-facing configuration — the thin facade left of the old
//! `PrefillEngine`.
//!
//! Execution itself lives behind the [`ExecBackend`](super::backend::ExecBackend)
//! trait in [`super::backend`]: `backend::native` (fused tiled kernels over
//! the paged store), `backend::reference` (the seed's row-serial executor,
//! kept as a drop-in conformance oracle) and `backend::pjrt` (AOT graphs via
//! PJRT, behind the `pjrt` cargo feature).  This module only defines the
//! knobs shared by every backend; construct a backend — or a whole serving
//! stack — through [`crate::serve::EngineBuilder`].

use crate::synth::SynthConfig;

/// Attention execution mode of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttentionMode {
    Dense,
    Sparse,
}

/// Knobs shared by every execution backend.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub synth: SynthConfig,
    /// Buckets served (must match artifacts for the PJRT backend).
    pub buckets: Vec<usize>,
    /// Block size of the tiled native executor.
    pub block_q: usize,
    /// Worker-pool size for the execution engine (kernels and the
    /// coordinator's batch fan-out).  0 = auto: `VSPREFILL_THREADS` env var,
    /// else available parallelism.
    pub threads: usize,
    /// Base cumulative-mass threshold of the budget selection (Eq. 18) at
    /// budget knob 0.5 — the paper's tau.
    pub budget_tau: f32,
    /// Decode budget: vertical columns kept per sparse decode step (top-k
    /// of the request's incrementally-maintained vertical index scores).
    pub decode_top_k: usize,
    /// Decode budget: local window of most recent positions always attended
    /// by a sparse decode step (the slash structure collapsed onto the
    /// single decode row).
    pub decode_window: usize,
    /// Run the adaptive per-head budget allocator (cumulative-threshold
    /// budgets per head with layer-level redistribution) instead of the
    /// uniform global-knob threshold.  Off by default; at the default taus
    /// the allocator reproduces the legacy selection exactly.
    pub adaptive_alloc: bool,
    /// Classify each head into a pattern family (vertical-slash / A-shape /
    /// block-sparse) at index time and lower the specialised families to
    /// narrower masks.  Off by default.
    pub pattern_select: bool,
    /// Budget policy family of the adaptive allocator:
    /// `cumulative` | `fixed` | `proportional` (validated at config load).
    pub budget_policy: String,
    /// Per-direction vertical threshold for the adaptive allocator.
    /// `0.0` (the default) means "follow `budget_tau`".
    pub tau_v: f32,
    /// Per-direction slash threshold for the adaptive allocator.
    /// `0.0` (the default) means "follow `budget_tau`".
    pub tau_s: f32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            synth: SynthConfig::default(),
            buckets: vec![128, 256, 512, 1024],
            block_q: 64,
            threads: 0,
            budget_tau: 0.9,
            decode_top_k: 64,
            decode_window: 64,
            adaptive_alloc: false,
            pattern_select: false,
            budget_policy: "cumulative".to_string(),
            tau_v: 0.0,
            tau_s: 0.0,
        }
    }
}
