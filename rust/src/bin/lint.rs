//! `vsprefill-lint` — run the in-tree invariant passes over the crate.
//!
//! ```text
//! cargo run --release --bin vsprefill-lint                     # lint only
//! cargo run --release --bin vsprefill-lint -- --check-inventory
//! cargo run --release --bin vsprefill-lint -- --write-inventory
//! cargo run --release --bin vsprefill-lint -- --root path/to/rust
//! ```
//!
//! Exit status is non-zero on any finding, and — with
//! `--check-inventory` — when `UNSAFE_INVENTORY.json` no longer matches
//! the tree (run `--write-inventory` and commit the diff).

use std::path::PathBuf;
use std::process::ExitCode;

use vsprefill::lint;

const INVENTORY: &str = "UNSAFE_INVENTORY.json";

fn main() -> ExitCode {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut write_inventory = false;
    let mut check_inventory = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("vsprefill-lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--write-inventory" => write_inventory = true,
            "--check-inventory" => check_inventory = true,
            other => {
                eprintln!("vsprefill-lint: unknown flag `{other}`");
                return ExitCode::from(2);
            }
        }
    }

    let cfg = match lint::locks::LockConfig::load(&root.join("lint/lock_order.toml")) {
        Ok(cfg) => cfg,
        Err(e) => {
            eprintln!("vsprefill-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let files = match lint::load_tree(&root) {
        Ok(files) => files,
        Err(e) => {
            eprintln!("vsprefill-lint: {e}");
            return ExitCode::from(2);
        }
    };

    let findings = lint::run_all(&files, &cfg);
    for f in &findings {
        println!("{f}");
    }

    let mut failed = !findings.is_empty();
    let json = lint::unsafe_audit::inventory_json(&files);
    let inv_path = root.join(INVENTORY);
    if write_inventory {
        if let Err(e) = std::fs::write(&inv_path, &json) {
            eprintln!("vsprefill-lint: cannot write {}: {e}", inv_path.display());
            return ExitCode::from(2);
        }
        println!("vsprefill-lint: wrote {}", inv_path.display());
    } else if check_inventory {
        match std::fs::read_to_string(&inv_path) {
            Ok(committed) if committed == json => {}
            Ok(_) => {
                eprintln!(
                    "vsprefill-lint: {INVENTORY} is stale — the unsafe surface changed; \
                     run `cargo run --release --bin vsprefill-lint -- --write-inventory` \
                     and commit the diff"
                );
                failed = true;
            }
            Err(e) => {
                eprintln!("vsprefill-lint: cannot read {}: {e}", inv_path.display());
                failed = true;
            }
        }
    }

    let sites: usize = files
        .iter()
        .filter(|f| f.is_src())
        .map(|f| lint::unsafe_audit::sites(f).len())
        .sum();
    println!(
        "vsprefill-lint: {} file(s), {} unsafe site(s), {} finding(s)",
        files.len(),
        sites,
        findings.len()
    );
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
