"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, block sizes and sparse index sets; every property
asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import synth
from compile.kernels import flash_attention as fa
from compile.kernels import ref
from compile.kernels import vs_aggregate as agg
from compile.kernels import vs_sparse_attention as vsa

SETTINGS = dict(max_examples=12, deadline=None)


def qkv(seed: int, n: int, d: int):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    return mk(), mk(), mk()


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([8, 16, 32]),
    bq=st.sampled_from([16, 32]),
)
def test_flash_matches_dense(seed, n, d, bq):
    q, k, v = qkv(seed, n, d)
    out = fa.flash_attention(q, k, v, block_q=bq, block_k=bq)
    want = ref.dense_attention(q, k, v)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_causality():
    """Perturbing future keys/values must not change earlier rows."""
    q, k, v = qkv(0, 64, 16)
    out1 = fa.flash_attention(q, k, v)
    k2 = k.at[40:].add(3.0)
    v2 = v.at[40:].add(-2.0)
    out2 = fa.flash_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:40], out2[:40], atol=1e-6)
    assert not np.allclose(out1[40:], out2[40:])


def test_flash_rows_are_convex_combinations():
    q, k, _ = qkv(1, 64, 16)
    v = jnp.ones((64, 16), jnp.float32)
    out = fa.flash_attention(q, k, v)
    np.testing.assert_allclose(out, 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# vs_aggregate (two-pass online aggregation)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([8, 32]),
    bq=st.sampled_from([16, 32]),
)
def test_lse_matches(seed, n, d, bq):
    q, k, _ = qkv(seed, n, d)
    got = agg.row_lse(q, k, block_q=bq, block_k=bq)
    np.testing.assert_allclose(got, ref.row_lse(q, k), atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([32, 64, 128]),
    bq=st.sampled_from([16, 32]),
    bk=st.sampled_from([16, 32]),
)
def test_vs_aggregate_matches(seed, n, bq, bk):
    q, k, _ = qkv(seed, n, 16)
    av, a_s = agg.vs_aggregate(q, k, block_q=bq, block_k=bk)
    av_ref, as_ref = ref.vs_aggregate(q, k)
    np.testing.assert_allclose(av, av_ref, atol=1e-6)
    np.testing.assert_allclose(a_s, as_ref, atol=1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([32, 64]))
def test_vs_aggregate_is_distribution(seed, n):
    """Both aggregates are nonnegative and sum to 1 (paper §4.2)."""
    q, k, _ = qkv(seed, n, 16)
    av, a_s = agg.vs_aggregate(q, k)
    assert float(jnp.min(av)) >= 0 and float(jnp.min(a_s)) >= 0
    np.testing.assert_allclose(float(jnp.sum(av)), 1.0, atol=1e-4)
    np.testing.assert_allclose(float(jnp.sum(a_s)), 1.0, atol=1e-4)


def test_vs_aggregate_detects_injected_verticals():
    """Heavy-hitter columns injected by the synth generator must dominate A_v."""
    rng = np.random.default_rng(3)
    q, k, _, info = synth.gen_qkv(rng, 128, synth.SynthConfig(n_heavy=4))
    av, _ = agg.vs_aggregate(jnp.asarray(q), jnp.asarray(k))
    top = set(np.argsort(-np.asarray(av))[: len(info["heavy"]) + 2].tolist())
    hits = len(top & set(info["heavy"].tolist()))
    assert hits >= len(info["heavy"]) - 1, (top, info["heavy"])


def test_slash_peak_at_zero_under_tied_means():
    """Appendix A.1, Eq. 28: with mu_q == mu_k every rotation plane has
    b_p = 0, so the expected score peaks exactly at offset 0."""
    rng = np.random.default_rng(4)
    cfg = synth.SynthConfig(tied_means=True, n_heavy=0, sink_tokens=0, query_align=0.0)
    q, k, _, _ = synth.gen_qkv(rng, 128, cfg)
    _, a_s = agg.vs_aggregate(jnp.asarray(q), jnp.asarray(k))
    assert int(np.argmax(np.asarray(a_s))) == 0


def test_slash_mass_is_concentrated():
    """Untied means move the peak but the offset distribution stays peaky —
    a few offsets must carry most of the slash mass (the paper's Fig. 4)."""
    rng = np.random.default_rng(4)
    cfg = synth.SynthConfig(n_heavy=0, sink_tokens=0, query_align=0.0, mean_scale=3.0)
    q, k, _, _ = synth.gen_qkv(rng, 128, cfg)
    _, a_s = agg.vs_aggregate(jnp.asarray(q), jnp.asarray(k))
    a_s = np.sort(np.asarray(a_s))[::-1]
    assert a_s[:16].sum() > 0.5 * a_s.sum()


# ---------------------------------------------------------------------------
# vs_sparse_attention (fused kernel)
# ---------------------------------------------------------------------------

def pad_idx(idx, cap, n):
    out = np.full((cap,), n, np.int32)
    out[: len(idx)] = np.asarray(idx, np.int32)
    return jnp.asarray(out)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([32, 64, 128]),
    nv=st.integers(1, 8),
    ns=st.integers(1, 6),
)
def test_sparse_matches_masked_reference(seed, n, nv, ns):
    rng = np.random.default_rng(seed)
    q, k, v = qkv(seed, n, 16)
    v_idx = np.sort(rng.choice(n, size=nv, replace=False))
    s_idx = np.unique(np.concatenate([[0], rng.choice(n, size=ns, replace=False)]))
    out = vsa.vs_sparse_attention(
        q, k, v,
        pad_idx(v_idx, 16, n), pad_idx(s_idx, 12, n),
        jnp.asarray([len(v_idx), len(s_idx)], jnp.int32),
        block_q=32 if n >= 32 else n,
    )
    want = ref.vs_sparse_attention(q, k, v, v_idx, s_idx)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_sparse_duplicate_indices_not_double_counted():
    """A column that is both vertical and on a selected slash must contribute
    exactly once to the softmax."""
    n = 64
    q, k, v = qkv(7, n, 16)
    # offset 0 makes column i a slash candidate of row i; also make col 10
    # vertical — for row 10 they coincide.
    v_idx = np.array([10], np.int32)
    s_idx = np.array([0], np.int32)
    out = vsa.vs_sparse_attention(
        q, k, v, pad_idx(v_idx, 8, n), pad_idx(s_idx, 8, n),
        jnp.asarray([1, 1], jnp.int32), block_q=32,
    )
    want = ref.vs_sparse_attention(q, k, v, v_idx, s_idx)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_sparse_full_budget_equals_dense():
    """Selecting every column reduces the sparse kernel to exact attention."""
    n = 32
    q, k, v = qkv(9, n, 8)
    v_idx = np.arange(n)
    out = vsa.vs_sparse_attention(
        q, k, v, pad_idx(v_idx, n, n), pad_idx([0], 4, n),
        jnp.asarray([n, 1], jnp.int32), block_q=16,
    )
    want = ref.dense_attention(q, k, v)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_sparse_padding_is_inert():
    """Growing the padded capacity must not change the result."""
    n = 64
    q, k, v = qkv(11, n, 16)
    v_idx, s_idx = np.array([0, 5]), np.array([0, 3])
    lens = jnp.asarray([2, 2], jnp.int32)
    a = vsa.vs_sparse_attention(q, k, v, pad_idx(v_idx, 4, n), pad_idx(s_idx, 4, n), lens)
    b = vsa.vs_sparse_attention(q, k, v, pad_idx(v_idx, 32, n), pad_idx(s_idx, 16, n), lens)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_recall_monotone_in_budget():
    """Adding indices can only increase attention recall (Eq. 6)."""
    rng = np.random.default_rng(5)
    q, k, _, _ = synth.gen_qkv(rng, 128, synth.SynthConfig())
    q, k = jnp.asarray(q), jnp.asarray(k)
    av, a_s = ref.vs_aggregate(q, k)
    order_v = np.argsort(-np.asarray(av))
    prev = 0.0
    for nv in (1, 4, 16, 64):
        keep = ref.vs_mask(128, order_v[:nv], np.array([0]))
        r = float(ref.attention_recall(q, k, keep))
        assert r >= prev - 1e-6
        prev = r
    assert prev > 0.3
