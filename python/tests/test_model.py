"""Toy GQA transformer (L2) shape/semantics tests."""

import jax.numpy as jnp
import numpy as np

from compile import model as mdl
from compile.kernels import ref

CFG = mdl.ModelConfig(vocab=64, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8, n_layers=2)


def params():
    return mdl.init_params(np.random.default_rng(0), CFG)


def test_prefill_dense_shapes():
    p = params()
    tokens = jnp.asarray(np.arange(32) % CFG.vocab, jnp.int32)
    logits, ks, vs = mdl.prefill_dense(p, tokens, CFG)
    assert logits.shape == (32, CFG.vocab)
    assert ks.shape == (CFG.n_layers, CFG.n_kv_heads, 32, CFG.head_dim)
    assert vs.shape == ks.shape
    assert np.all(np.isfinite(np.asarray(logits)))


def test_prefill_causality():
    """Changing a suffix token must not affect earlier logits."""
    p = params()
    t1 = jnp.asarray(np.arange(32) % CFG.vocab, jnp.int32)
    t2 = t1.at[20:].set(7)
    l1, _, _ = mdl.prefill_dense(p, t1, CFG)
    l2, _, _ = mdl.prefill_dense(p, t2, CFG)
    np.testing.assert_allclose(l1[:20], l2[:20], atol=1e-4)


def test_sparse_prefill_full_budget_matches_dense():
    """With every column selected, sparse prefill == dense prefill."""
    p = params()
    n = 32
    tokens = jnp.asarray(np.arange(n) % CFG.vocab, jnp.int32)
    kv_cap, ks_cap = n, 4
    vi = np.tile(np.arange(n, dtype=np.int32), (CFG.n_layers, CFG.n_kv_heads, 1))
    si = np.full((CFG.n_layers, CFG.n_kv_heads, ks_cap), n, np.int32)
    si[:, :, 0] = 0
    lens = np.tile(np.asarray([n, 1], np.int32), (CFG.n_layers, CFG.n_kv_heads, 1))
    sparse = mdl.prefill_sparse(p, tokens, jnp.asarray(vi), jnp.asarray(si), jnp.asarray(lens), CFG)
    dense, _, _ = mdl.prefill_dense(p, tokens, CFG)
    np.testing.assert_allclose(sparse, dense, atol=1e-3, rtol=1e-3)


def test_sparse_prefill_degrades_gracefully():
    """A tight-but-sane budget must stay finite and close-ish to dense."""
    p = params()
    n = 32
    tokens = jnp.asarray((np.arange(n) * 3) % CFG.vocab, jnp.int32)
    kv_cap, ks_cap = 8, 4
    vi = np.full((CFG.n_layers, CFG.n_kv_heads, kv_cap), n, np.int32)
    vi[:, :, :4] = np.arange(4)
    si = np.full((CFG.n_layers, CFG.n_kv_heads, ks_cap), n, np.int32)
    si[:, :, 0] = 0
    si[:, :, 1] = 1
    lens = np.tile(np.asarray([4, 2], np.int32), (CFG.n_layers, CFG.n_kv_heads, 1))
    sparse = mdl.prefill_sparse(p, tokens, jnp.asarray(vi), jnp.asarray(si), jnp.asarray(lens), CFG)
    assert np.all(np.isfinite(np.asarray(sparse)))


def test_flatten_unflatten_roundtrip():
    p = params()
    flat = mdl.flatten_params(p, CFG)
    p2 = mdl.unflatten_params([a for _, a in flat], CFG)
    tokens = jnp.asarray(np.arange(16) % CFG.vocab, jnp.int32)
    l1, _, _ = mdl.prefill_dense(p, tokens, CFG)
    l2, _, _ = mdl.prefill_dense(p2, tokens, CFG)
    np.testing.assert_allclose(l1, l2)


def test_rope_preserves_norm_and_relativity():
    """R(t) is orthogonal; q·R(m-n)k == (R(m)q)·(R(n)k)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    y = ref.rope(x)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=1),
        np.linalg.norm(np.asarray(y), axis=1),
        rtol=1e-5,
    )
    # relativity: scores depend only on offset for constant inputs
    q = jnp.tile(x[:1], (8, 1))
    k = jnp.tile(x[1:2], (8, 1))
    qr, kr = ref.rope(q), ref.rope(k)
    s = np.asarray(qr @ kr.T)
    for off in range(1, 4):
        d = np.diagonal(s, -off)
        np.testing.assert_allclose(d, d[0], rtol=1e-4, atol=1e-4)
