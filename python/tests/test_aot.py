"""AOT pipeline tests: artifacts exist, parse as HLO text, manifest is sane.

These run against the bundle produced by ``make artifacts`` when present;
otherwise they lower a single small graph in-process to validate the HLO-text
path end-to-end (the full bundle is exercised by the Rust integration tests).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrippable():
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_caps_monotone():
    prev = (0, 0)
    for n in (256, 512, 1024, 4096):
        caps = aot.caps_for(n)
        assert caps[0] >= prev[0] and caps[1] >= prev[1]
        assert caps[0] <= n and caps[1] <= n
        prev = caps


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifact bundle not built")
def test_manifest_consistent_with_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["head_dim"] == aot.HEAD_DIM
    for name, g in manifest["graphs"].items():
        path = os.path.join(ART, g["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, name
        assert len(g["args"]) >= 1


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "indexer_weights.json")),
                    reason="artifact bundle not built")
def test_exported_indexer_weights_shapes():
    with open(os.path.join(ART, "indexer_weights.json")) as f:
        w = json.load(f)
    d, h = w["head_dim"], w["hidden"]
    shapes = {k: v["shape"] for k, v in w["weights"].items()}
    assert shapes["wu"] == [2 * d, h]
    assert shapes["wv"] == [h, 1] and shapes["ws"] == [h, 1]
    for v in w["weights"].values():
        assert len(v["data"]) == int(np.prod(v["shape"]))
        assert all(np.isfinite(v["data"]))
