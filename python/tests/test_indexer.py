"""VSIndexer forward/distillation tests (L2)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import indexer as ix
from compile import synth
from compile.kernels import ref

CFG = ix.IndexerConfig(head_dim=32, hidden=64)


def test_forward_outputs_distributions():
    rng = np.random.default_rng(0)
    p = ix.init_indexer(rng, CFG)
    k = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    av, a_s = ix.indexer_forward(p, k, v)
    assert av.shape == (64,) and a_s.shape == (64,)
    np.testing.assert_allclose(float(av.sum()), 1.0, atol=1e-5)
    np.testing.assert_allclose(float(a_s.sum()), 1.0, atol=1e-5)
    assert float(av.min()) >= 0 and float(a_s.min()) >= 0


def test_slash_alignment_convention():
    """The slash score at offset o must come from position n-1-o."""
    rng = np.random.default_rng(1)
    p = ix.init_indexer(rng, CFG)
    k = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    _, a_s = ix.indexer_forward(p, k, v)
    # Recompute by hand.
    import jax

    x = jnp.concatenate([k, v], -1)
    z = jax.nn.silu(x @ p["wu"] + p["bu"])
    logits = (z @ p["ws"] + p["bs"])[:, 0]
    want = jax.nn.softmax(logits[::-1])
    np.testing.assert_allclose(a_s, want, atol=1e-6)


@pytest.mark.parametrize("loss", ["kl", "mse", "msle", "cosine"])
def test_losses_zero_at_match_and_positive(loss):
    rng = np.random.default_rng(2)
    t = rng.random(32).astype(np.float32)
    t /= t.sum()
    fn = ix.LOSSES[loss]
    t = jnp.asarray(t)
    assert abs(float(fn(t, t))) < 1e-5
    u = jnp.roll(t, 3)
    assert float(fn(u, t)) > 1e-4


def test_distillation_reduces_loss_and_learns_verticals():
    tc = ix.TrainConfig(steps=150, batch=4, seq_len=128, loss="kl", seed=0)
    params, hist = ix.distill(CFG, tc)
    early = float(np.mean(hist[:5]))
    late = float(np.mean(hist[-5:]))
    assert late < early * 0.5, (early, late)

    # The trained indexer should rank injected heavy-hitter columns highly.
    rng = np.random.default_rng(99)
    q, k, v, info = synth.gen_qkv(rng, 128, tc.synth_cfg, head_seed=0)
    av, _ = ix.indexer_forward(params, jnp.asarray(k), jnp.asarray(v))
    top = set(np.argsort(-np.asarray(av))[:12].tolist())
    hits = len(top & set(info["heavy"].tolist()))
    assert hits >= len(info["heavy"]) // 2, (sorted(top), info["heavy"])


def test_trained_recall_beats_random():
    tc = ix.TrainConfig(steps=150, batch=4, seq_len=128, loss="kl", seed=1)
    params, _ = ix.distill(CFG, tc)
    rng = np.random.default_rng(5)
    r_learned = ix.recall_at_sparsity(params, rng, sparsity=0.9, n=128, trials=4)

    # Random baseline with the same budget split.
    rng2 = np.random.default_rng(5)
    total = 0.0
    n = 128
    for t in range(4):
        q, k, _, _ = synth.gen_qkv(rng2, n, tc.synth_cfg, head_seed=t % 8)
        keep_cells = 0.1 * (n * (n + 1) / 2)
        cols = max(1, int(keep_cells / 2 / (n / 2)))
        offs = max(1, int(keep_cells / 2 / (n / 2)))
        ridx = np.random.default_rng(t)
        keep = ref.vs_mask(n, ridx.choice(n, cols, replace=False), ridx.choice(n, offs, replace=False))
        total += float(ref.attention_recall(jnp.asarray(q), jnp.asarray(k), keep))
    r_random = total / 4
    assert r_learned > r_random + 0.1, (r_learned, r_random)
