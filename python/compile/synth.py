"""Synthetic attention-input generator implementing the paper's Appendix A.1
generative model.

Q/K feature dimensions are drawn from per-dimension Gaussians with structured
means (the paper validates this on Qwen3-4B activations, Fig. 8); under RoPE
the expected score E[P_mn] = mu_q^T R(m-n) mu_k depends only on the relative
offset m-n (Eq. 23-28), which *produces* slash lines.  Vertical lines are
produced by injecting "heavy-hitter" key positions whose keys align with a
direction shared by all queries.  The Rust twin of this module lives in
rust/src/synth/ and follows the same parameterization so distilled indexer
weights transfer.

Two "model family" presets (qwen_sim / llama_sim) differ in RoPE base, mean
scale and heavy-hitter statistics to reproduce the paper's model-dependence
observations (Fig. 3e-f).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SynthConfig:
    """Parameters of the Gaussian+RoPE attention generator."""

    head_dim: int = 32
    rope_base: float = 10000.0
    mean_scale: float = 1.2       # |mu_q|, |mu_k| scale -> slash strength
    noise_scale: float = 0.7      # per-dim Gaussian std
    n_heavy: int = 4              # number of heavy-hitter (vertical) columns
    heavy_strength: float = 16.0  # key alignment boost for heavy hitters
    sink_tokens: int = 2          # initial attention-sink columns
    sink_boost: float = 1.4       # sinks are stronger than ordinary heavies
    query_align: float = 3.0      # query component along the heavy direction
    seed_means: int = 7           # seed for the per-head mean vectors
    tied_means: bool = False      # mu_q == mu_k => slash phase alpha_p = 0,
    #                               so the expected-score peak sits at offset 0
    #                               (Eq. 28 with b_p = 0) — used by tests/figs


# Calibrated so the oracle VS mask reproduces the paper's recall/sparsity
# shape (Table 3): >97% recall at ~50% sparsity, ~72% at ~90% sparsity.
QWEN_SIM = SynthConfig(mean_scale=1.2, n_heavy=4, heavy_strength=16.0, rope_base=10000.0)
LLAMA_SIM = SynthConfig(mean_scale=1.0, n_heavy=6, heavy_strength=18.0, rope_base=500000.0)


def _rope_np(x: np.ndarray, base: float) -> np.ndarray:
    n, d = x.shape
    half = d // 2
    theta = base ** (-np.arange(half) * 2.0 / d)
    ang = np.arange(n)[:, None] * theta[None, :]
    cos, sin = np.cos(ang), np.sin(ang)
    out = np.empty_like(x)
    out[:, 0::2] = x[:, 0::2] * cos - x[:, 1::2] * sin
    out[:, 1::2] = x[:, 0::2] * sin + x[:, 1::2] * cos
    return out


def gen_qkv(
    rng: np.random.Generator,
    n: int,
    cfg: SynthConfig = SynthConfig(),
    head_seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, dict]:
    """Sample one head's (Q_rope, K_rope, V) with vertical-slash structure.

    Returns float32 arrays of shape (n, d) and an info dict with the injected
    heavy-hitter positions (ground truth for evaluation tasks).
    """
    d = cfg.head_dim
    mean_rng = np.random.default_rng(cfg.seed_means + 1000 * head_seed)
    mu_q = mean_rng.normal(size=d) * cfg.mean_scale
    mu_k = mu_q.copy() if cfg.tied_means else mean_rng.normal(size=d) * cfg.mean_scale
    # Heavy-hitter direction is per-context (content stream), not per-head:
    # the indexer must detect boosted keys along *any* direction.
    u = rng.normal(size=d)
    u /= np.linalg.norm(u)

    q = rng.normal(size=(n, d)) * cfg.noise_scale + mu_q
    k = rng.normal(size=(n, d)) * cfg.noise_scale + mu_k

    q = _rope_np(q, cfg.rope_base)
    k = _rope_np(k, cfg.rope_base)

    # Heavy hitters: a few random positions plus the initial sink tokens get
    # keys boosted along u *after* RoPE, and queries a matching component —
    # a position-independent content alignment (the attention-sink
    # phenomenon), which is what makes the columns vertical: they attract
    # mass from all rows regardless of relative position.
    n_hh = min(cfg.n_heavy, max(n - cfg.sink_tokens, 0))
    heavy = rng.choice(np.arange(cfg.sink_tokens, n), size=n_hh, replace=False) if n_hh else np.array([], int)
    sinks = np.arange(min(cfg.sink_tokens, n))
    hh = np.concatenate([sinks, heavy]).astype(int)
    k[hh] += cfg.heavy_strength * u
    k[sinks] += (cfg.sink_boost - 1.0) * cfg.heavy_strength * u
    q += cfg.query_align * u
    q = q.astype(np.float32)
    k = k.astype(np.float32)
    v = rng.normal(size=(n, d)).astype(np.float32)
    return q, k, v, {"heavy": np.sort(hh)}
