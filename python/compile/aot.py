"""AOT pipeline: train the VSIndexer, lower every compute graph to HLO text,
and emit the artifact bundle the Rust runtime consumes.

Interchange format is HLO *text* (never ``.serialize()``): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Artifacts (per sequence-length bucket n, head_dim d):
  flash_attn_{n}.hlo.txt    (q,k,v) -> (out,)                exact baseline
  vs_aggregate_{n}.hlo.txt  (q,k) -> (av, as)                ground truth (§4.2)
  indexer_{n}.hlo.txt       (k,v,wu,bu,wv,bv,ws,bs) -> (av, as)   VSIndexer fwd
  sparse_attn_{n}.hlo.txt   (q,k,v,vidx,sidx,lens) -> (out,) fused VS kernel
  model_prefill_{n}.hlo.txt (tokens, *weights) -> (logits, ks, vs)
plus indexer_weights.json, model_weights.json and manifest.json.

Weights are *runtime arguments* of the graphs (not baked constants) so one
artifact serves any weight set; Rust feeds them from the JSON exports.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import indexer as ix
from . import model as mdl
from .kernels import flash_attention as fa
from .kernels import vs_aggregate as agg
from .kernels import vs_sparse_attention as vsa

BUCKETS = (256, 512, 1024)
HEAD_DIM = 32
MODEL_BUCKETS = (256,)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to(path: str, fn, *specs) -> dict:
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {
        "file": os.path.basename(path),
        "args": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs],
    }


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def caps_for(n: int) -> tuple[int, int]:
    """Static capacities of the padded index lists per bucket."""
    return max(32, n // 8), max(16, n // 16)


def array_to_json(a) -> dict:
    a = np.asarray(a)
    return {"shape": list(a.shape), "data": [float(x) for x in a.reshape(-1)]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true", help="skip the 512/1024 buckets")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    d = HEAD_DIM
    buckets = BUCKETS[:1] if args.quick else BUCKETS

    manifest: dict = {
        "head_dim": d,
        "buckets": list(buckets),
        "model": {
            "vocab": mdl.TINY.vocab,
            "d_model": mdl.TINY.d_model,
            "n_heads": mdl.TINY.n_heads,
            "n_kv_heads": mdl.TINY.n_kv_heads,
            "head_dim": mdl.TINY.head_dim,
            "n_layers": mdl.TINY.n_layers,
            "rope_base": mdl.TINY.rope_base,
        },
        "graphs": {},
    }

    # ---- 1. Distill the VSIndexer --------------------------------------
    print("[aot] distilling VSIndexer ...")
    icfg = ix.IndexerConfig(head_dim=d, hidden=64)
    tc = ix.TrainConfig(steps=args.steps, batch=4, seq_len=256, loss="kl", seed=0)
    iparams, hist = ix.distill(icfg, tc, log_every=50)
    print(f"[aot] distill final loss {hist[-1]:.4f}")
    with open(os.path.join(args.out, "indexer_weights.json"), "w") as f:
        json.dump(
            {
                "hidden": icfg.hidden,
                "head_dim": d,
                "final_loss": hist[-1],
                "weights": {k: array_to_json(v) for k, v in iparams.items()},
            },
            f,
        )

    # ---- 2. Model weights ----------------------------------------------
    mrng = np.random.default_rng(42)
    mparams = mdl.init_params(mrng, mdl.TINY)
    flat = mdl.flatten_params(mparams, mdl.TINY)
    with open(os.path.join(args.out, "model_weights.json"), "w") as f:
        json.dump({"names": [n for n, _ in flat],
                   "weights": {n: array_to_json(a) for n, a in flat}}, f)

    # ---- 3. Per-bucket kernels ------------------------------------------
    for n in buckets:
        kv_cap, ks_cap = caps_for(n)
        manifest["graphs"][f"flash_attn_{n}"] = lower_to(
            os.path.join(args.out, f"flash_attn_{n}.hlo.txt"),
            lambda q, k, v: (fa.flash_attention(q, k, v),),
            f32(n, d), f32(n, d), f32(n, d),
        )
        manifest["graphs"][f"vs_aggregate_{n}"] = lower_to(
            os.path.join(args.out, f"vs_aggregate_{n}.hlo.txt"),
            lambda q, k: agg.vs_aggregate(q, k),
            f32(n, d), f32(n, d),
        )
        manifest["graphs"][f"indexer_{n}"] = lower_to(
            os.path.join(args.out, f"indexer_{n}.hlo.txt"),
            lambda k, v, wu, bu, wv, bv, ws, bs: ix.indexer_forward(
                dict(wu=wu, bu=bu, wv=wv, bv=bv, ws=ws, bs=bs), k, v
            ),
            f32(n, d), f32(n, d),
            f32(2 * d, icfg.hidden), f32(icfg.hidden),
            f32(icfg.hidden, 1), f32(1), f32(icfg.hidden, 1), f32(1),
        )
        manifest["graphs"][f"sparse_attn_{n}"] = lower_to(
            os.path.join(args.out, f"sparse_attn_{n}.hlo.txt"),
            lambda q, k, v, vi, si, ln: (vsa.vs_sparse_attention(q, k, v, vi, si, ln),),
            f32(n, d), f32(n, d), f32(n, d), i32(kv_cap), i32(ks_cap), i32(2),
        )
        manifest["graphs"][f"sparse_attn_{n}"]["caps"] = [kv_cap, ks_cap]
        print(f"[aot] bucket {n} lowered (caps kv={kv_cap} ks={ks_cap})")

    # ---- 4. Whole-model prefill graphs ----------------------------------
    cfg = mdl.TINY
    for n in MODEL_BUCKETS:
        weight_specs = [f32(*a.shape) for _, a in flat]

        def prefill_fn(tokens, *weights):
            params = mdl.unflatten_params(list(weights), cfg)
            return mdl.prefill_dense(params, tokens, cfg)

        manifest["graphs"][f"model_prefill_{n}"] = lower_to(
            os.path.join(args.out, f"model_prefill_{n}.hlo.txt"),
            prefill_fn, i32(n), *weight_specs,
        )
        kv_cap, ks_cap = caps_for(n)

        def sparse_prefill_fn(tokens, vi, si, ln, *weights):
            params = mdl.unflatten_params(list(weights), cfg)
            return (mdl.prefill_sparse(params, tokens, vi, si, ln, cfg),)

        manifest["graphs"][f"model_prefill_sparse_{n}"] = lower_to(
            os.path.join(args.out, f"model_prefill_sparse_{n}.hlo.txt"),
            sparse_prefill_fn,
            i32(n),
            i32(cfg.n_layers, cfg.n_kv_heads, kv_cap),
            i32(cfg.n_layers, cfg.n_kv_heads, ks_cap),
            i32(cfg.n_layers, cfg.n_kv_heads, 2),
            *weight_specs,
        )
        manifest["graphs"][f"model_prefill_sparse_{n}"]["caps"] = [kv_cap, ks_cap]
        print(f"[aot] model prefill {n} lowered")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['graphs'])} graphs to {args.out}")


if __name__ == "__main__":
    main()
