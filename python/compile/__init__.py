"""Build-time Python package for VSPrefill: kernels (L1), model/indexer (L2),
and the AOT pipeline that lowers everything to artifacts consumed by the Rust
coordinator (L3).  Never imported at runtime."""
