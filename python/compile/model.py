"""L2 — toy GQA transformer in JAX, calling the L1 kernels.

A small grouped-query-attention decoder stack standing in for the paper's
Qwen3-4B / LLaMA-3.1-8B backbones (DESIGN.md substitution #1).  The prefill
path is expressed twice:

  * ``prefill_dense``  — exact attention via the flash kernel; also returns
    the per-layer RoPE'd K and V tensors the VSIndexer consumes.
  * ``prefill_sparse`` — vertical-slash sparse attention via the fused kernel
    given per-layer/group index lists.

Both are AOT-lowered by ``aot.py`` to HLO text per sequence-length bucket and
executed from Rust; Python never runs at serving time.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import flash_attention as fa
from .kernels import ref
from .kernels import vs_sparse_attention as vsa


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    n_layers: int = 2
    mlp_ratio: int = 4
    rope_base: float = 10000.0

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads


TINY = ModelConfig()


def init_params(rng: np.random.Generator, cfg: ModelConfig = TINY) -> dict:
    """He-style random init; returns a pytree of float32 jnp arrays."""

    def w(*shape, scale=None):
        s = scale if scale is not None else (2.0 / shape[0]) ** 0.5
        return jnp.asarray(rng.normal(size=shape) * s, jnp.float32)

    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            dict(
                wq=w(cfg.d_model, cfg.n_heads * cfg.head_dim),
                wk=w(cfg.d_model, cfg.n_kv_heads * cfg.head_dim),
                wv=w(cfg.d_model, cfg.n_kv_heads * cfg.head_dim),
                wo=w(cfg.n_heads * cfg.head_dim, cfg.d_model),
                w1=w(cfg.d_model, cfg.mlp_ratio * cfg.d_model),
                w2=w(cfg.mlp_ratio * cfg.d_model, cfg.d_model),
                ln1=jnp.ones((cfg.d_model,), jnp.float32),
                ln2=jnp.ones((cfg.d_model,), jnp.float32),
            )
        )
    return dict(
        embed=w(cfg.vocab, cfg.d_model, scale=0.02),
        lnf=jnp.ones((cfg.d_model,), jnp.float32),
        layers=layers,
    )


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    return x * g / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def layer_qkv(lp: dict, x: jnp.ndarray, cfg: ModelConfig):
    """Project and RoPE one layer's Q/K/V.

    Returns q (H, n, d), k (KV, n, d), v (KV, n, d) — K already RoPE'd, which
    is exactly the representation the VSIndexer takes as input (§4.1).
    """
    n = x.shape[0]
    h = rmsnorm(x, lp["ln1"])
    q = (h @ lp["wq"]).reshape(n, cfg.n_heads, cfg.head_dim).transpose(1, 0, 2)
    k = (h @ lp["wk"]).reshape(n, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)
    v = (h @ lp["wv"]).reshape(n, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)
    rope = functools.partial(ref.rope, base=cfg.rope_base)
    q = jax.vmap(rope)(q)
    k = jax.vmap(rope)(k)
    return q, k, v


def _attn_out_to_residual(lp: dict, x: jnp.ndarray, heads_out: jnp.ndarray, cfg: ModelConfig):
    n = x.shape[0]
    o = heads_out.transpose(1, 0, 2).reshape(n, cfg.n_heads * cfg.head_dim)
    x = x + o @ lp["wo"]
    h = rmsnorm(x, lp["ln2"])
    return x + jax.nn.silu(h @ lp["w1"]) @ lp["w2"]


def prefill_dense(params: dict, tokens: jnp.ndarray, cfg: ModelConfig = TINY):
    """Exact prefill. Returns (logits, ks, vs) with ks/vs stacked as
    (n_layers, n_kv_heads, n, head_dim); K is post-RoPE."""
    x = params["embed"][tokens]
    ks, vs = [], []
    for lp in params["layers"]:
        q, k, v = layer_qkv(lp, x, cfg)
        ks.append(k)
        vs.append(v)
        kg = jnp.repeat(k, cfg.group_size, axis=0)
        vg = jnp.repeat(v, cfg.group_size, axis=0)
        heads_out = jax.vmap(fa.flash_attention)(q, kg, vg)
        x = _attn_out_to_residual(lp, x, heads_out, cfg)
    logits = rmsnorm(x, params["lnf"]) @ params["embed"].T
    return logits, jnp.stack(ks), jnp.stack(vs)


def prefill_sparse(
    params: dict,
    tokens: jnp.ndarray,
    v_idx: jnp.ndarray,
    s_idx: jnp.ndarray,
    lens: jnp.ndarray,
    cfg: ModelConfig = TINY,
):
    """Sparse prefill: per-(layer, kv-group) vertical/slash index lists.

    Args:
      v_idx: (n_layers, n_kv_heads, kv_cap) int32, padded with n.
      s_idx: (n_layers, n_kv_heads, ks_cap) int32, padded with n.
      lens:  (n_layers, n_kv_heads, 2) int32 true lengths.
    Returns logits (n, vocab).
    """
    x = params["embed"][tokens]
    for li, lp in enumerate(params["layers"]):
        q, k, v = layer_qkv(lp, x, cfg)
        kg = jnp.repeat(k, cfg.group_size, axis=0)
        vg = jnp.repeat(v, cfg.group_size, axis=0)
        vi = jnp.repeat(v_idx[li], cfg.group_size, axis=0)
        si = jnp.repeat(s_idx[li], cfg.group_size, axis=0)
        ln = jnp.repeat(lens[li], cfg.group_size, axis=0)
        heads_out = jax.vmap(vsa.vs_sparse_attention)(q, kg, vg, vi, si, ln)
        x = _attn_out_to_residual(lp, x, heads_out, cfg)
    return rmsnorm(x, params["lnf"]) @ params["embed"].T


def flatten_params(params: dict, cfg: ModelConfig = TINY) -> list[tuple[str, jnp.ndarray]]:
    """Deterministic (name, array) list for weight export / AOT arguments."""
    out = [("embed", params["embed"]), ("lnf", params["lnf"])]
    for i, lp in enumerate(params["layers"]):
        for key in ("wq", "wk", "wv", "wo", "w1", "w2", "ln1", "ln2"):
            out.append((f"layers.{i}.{key}", lp[key]))
    return out


def unflatten_params(flat: list[jnp.ndarray], cfg: ModelConfig = TINY) -> dict:
    """Inverse of flatten_params given arrays in the same order."""
    it = iter(flat)
    params = dict(embed=next(it), lnf=next(it), layers=[])
    for _ in range(cfg.n_layers):
        lp = {}
        for key in ("wq", "wk", "wv", "wo", "w1", "w2", "ln1", "ln2"):
            lp[key] = next(it)
        params["layers"].append(lp)
    return params
