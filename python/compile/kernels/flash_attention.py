"""Dense causal FlashAttention Pallas kernel — the exact-attention baseline.

Standard streaming-softmax recurrence (Dao et al., 2022): gridded over query
blocks, iterating key blocks with running (max, sumexp, output) accumulators
that are rescaled whenever the running max moves.  Serves two purposes:

  * the FlashAttn rows of Tables 1-2 / Figure 5 (exact baseline);
  * the computation-flow skeleton that ``vs_aggregate`` extends with online
    aggregation, so the two kernels share their tiling conventions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, n: int, scale: float):
    qi = pl.program_id(0)
    q = q_ref[...]
    block_q, d = q.shape
    rows = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    num_kb = n // block_k

    def body(kb, carry):
        m, s, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        cols = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        p = jnp.dot(q, k.T) * scale
        p = jnp.where(cols[None, :] <= rows[:, None], p, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(p, axis=-1))
        alpha = jnp.exp(m - m_new)
        e = jnp.exp(p - m_new[:, None])
        s_new = s * alpha + jnp.sum(e, axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(e, v)
        return m_new, s_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    s0 = jnp.zeros((block_q,), dtype=jnp.float32)
    a0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m, s, acc = jax.lax.fori_loop(0, num_kb, body, (m0, s0, a0))
    o_ref[...] = acc / s[:, None]


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, block_q: int = 64, block_k: int = 64
) -> jnp.ndarray:
    """Exact causal attention via the streaming-softmax kernel; (n, d) in/out."""
    n, d = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    assert n % block_q == 0 and n % block_k == 0
    kernel = functools.partial(_flash_kernel, block_k=block_k, n=n, scale=1.0 / d**0.5)
    return pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(q, k, v)
