"""L1 — Pallas kernels for VSPrefill (interpret=True on CPU).

Modules:
  ref                 pure-jnp oracles (materialize n x n; test scale only)
  flash_attention     dense causal streaming-softmax baseline
  vs_aggregate        two-pass online vertical/slash aggregation (§4.2)
  vs_sparse_attention fused vertical-slash sparse attention (§4.3)
"""

from . import flash_attention, ref, vs_aggregate, vs_sparse_attention  # noqa: F401
