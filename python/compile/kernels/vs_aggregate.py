"""Pallas online vertical-slash aggregation kernel.

Reproduces the paper's "customized FlashAttention kernel … that preserves the
original computation flow while performing online aggregation during
block-wise attention computation" (§4.2) without ever materializing the
``n x n`` attention matrix.

Two passes, both gridded over query blocks:

  pass 1 (``row_lse_kernel``)  — streaming-softmax statistics: for each query
      block, iterate over key blocks keeping a running (max, sumexp) pair and
      emit the per-row logsumexp.  This is exactly the FlashAttention
      normalizer recurrence.
  pass 2 (``aggregate_kernel``) — with the row normalizers known, each score
      tile can be exponentiated into *final* probabilities, so contributions
      to the vertical accumulator (column sums) and the slash accumulator
      (anti-diagonal sums) can be added directly; the slash scatter uses a
      segment-sum keyed by the global offset ``i - j``.

VMEM per grid step (pass 2): one (block_q x block_k) score tile, a
(block_q, d) Q tile, a (block_k, d) K tile and two length-n accumulator
stripes — linear in n, independent of n^2.

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the supported lowering for both the
pytest oracle checks and the AOT artifacts consumed by the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _row_lse_kernel(q_ref, k_ref, lse_ref, *, block_k: int, n: int, scale: float):
    """Grid: (num_q_blocks,). Streams K in ``block_k`` tiles."""
    qi = pl.program_id(0)
    q = q_ref[...]  # (block_q, d)
    block_q = q.shape[0]
    row0 = qi * block_q
    rows = row0 + jax.lax.iota(jnp.int32, block_q)

    num_kb = n // block_k

    def body(kb, carry):
        m, s = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        cols = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        p = jnp.dot(q, k.T) * scale
        p = jnp.where(cols[None, :] <= rows[:, None], p, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(p, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(jnp.exp(p - m_new[:, None]), axis=-1)
        return m_new, s

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    s0 = jnp.zeros((block_q,), dtype=jnp.float32)
    m, s = jax.lax.fori_loop(0, num_kb, body, (m0, s0))
    lse_ref[...] = m + jnp.log(s)


def _aggregate_kernel(
    q_ref, k_ref, lse_ref, av_ref, as_ref, *, block_k: int, n: int, scale: float
):
    """Grid: (num_q_blocks,). Accumulates A_v / A_s across grid steps."""
    qi = pl.program_id(0)

    @pl.when(qi == 0)
    def _init():
        av_ref[...] = jnp.zeros_like(av_ref)
        as_ref[...] = jnp.zeros_like(as_ref)

    q = q_ref[...]
    block_q = q.shape[0]
    row0 = qi * block_q
    rows = row0 + jax.lax.iota(jnp.int32, block_q)
    lse = lse_ref[...]

    num_kb = n // block_k

    def body(kb, carry):
        av_acc, as_acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        cols = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        p = jnp.dot(q, k.T) * scale
        causal = cols[None, :] <= rows[:, None]
        # Final probabilities: the row normalizer is already known.
        prob = jnp.where(causal, jnp.exp(p - lse[:, None]), 0.0)
        # Vertical: column sums, scattered at this key block's offset.
        col_sums = jnp.sum(prob, axis=0)
        av_acc = jax.lax.dynamic_update_slice(
            av_acc,
            jax.lax.dynamic_slice(av_acc, (kb * block_k,), (block_k,)) + col_sums,
            (kb * block_k,),
        )
        # Slash: segment-sum keyed by global offset i - j (causal => >= 0).
        off = rows[:, None] - cols[None, :]
        as_acc = as_acc + jax.ops.segment_sum(
            prob.reshape(-1),
            jnp.clip(off, 0, n - 1).reshape(-1),
            num_segments=n,
        )
        return av_acc, as_acc

    av_acc, as_acc = jax.lax.fori_loop(
        0, num_kb, body, (jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32))
    )
    av_ref[...] += av_acc
    as_ref[...] += as_acc


def row_lse(q: jnp.ndarray, k: jnp.ndarray, *, block_q: int = 64, block_k: int = 64):
    """Per-row logsumexp of scaled causal scores via the pass-1 kernel."""
    n, d = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    assert n % block_q == 0 and n % block_k == 0
    scale = 1.0 / (d**0.5)
    kernel = functools.partial(_row_lse_kernel, block_k=block_k, n=n, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(q, k)


def vs_aggregate(
    q: jnp.ndarray, k: jnp.ndarray, *, block_q: int = 64, block_k: int = 64
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Online vertical/slash aggregation: returns (A_v, A_s), each (n,) and
    normalized to sum to 1, matching ``ref.vs_aggregate`` exactly."""
    n, d = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    assert n % block_q == 0 and n % block_k == 0
    scale = 1.0 / (d**0.5)
    lse = row_lse(q, k, block_q=block_q, block_k=block_k)
    kernel = functools.partial(_aggregate_kernel, block_k=block_k, n=n, scale=scale)
    a_v, a_s = pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(q, k, lse)
    return a_v / n, a_s / n
