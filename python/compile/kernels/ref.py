"""Pure-jnp correctness oracles for the VSPrefill kernels.

Everything here materializes the full ``n x n`` attention matrix and is
therefore only usable at test scale.  The Pallas kernels in this package
(``vs_aggregate``, ``vs_sparse_attention``, ``flash_attention``) must agree
with these references to within float tolerance; ``python/tests`` enforces
that with hypothesis sweeps over shapes and pattern parameters.

Conventions (shared with the Rust side — see rust/src/attention/):
  * All attention is causal.
  * ``A_v[j]``  = (1/n) * sum_i A[i, j]                (vertical column mass)
  * ``A_s[o]``  = (1/n) * sum_{i-j==o} A[i, j]         (slash/offset mass),
    offsets o in [0, n); both vectors sum to 1 for causal attention.
  * A vertical-slash mask keeps cell (i, j) iff ``j in I_v`` or
    ``(i - j) in I_s`` (Eq. 9 of the paper), intersected with causality.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def rope(x: jnp.ndarray, base: float = 10000.0, offset: int = 0) -> jnp.ndarray:
    """Apply rotary positional embedding to ``x`` of shape (n, d), d even.

    Pairs dimension 2p with 2p+1 and rotates by ``t * theta_p`` with
    ``theta_p = base ** (-2p / d)`` — Eq. 22 of the paper.
    """
    n, d = x.shape
    assert d % 2 == 0, "rope requires an even head dimension"
    half = d // 2
    theta = base ** (-jnp.arange(half, dtype=jnp.float32) * 2.0 / d)
    t = jnp.arange(n, dtype=jnp.float32)[:, None] + float(offset)
    ang = t * theta[None, :]  # (n, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x_even = x[:, 0::2]
    x_odd = x[:, 1::2]
    out = jnp.stack([x_even * cos - x_odd * sin, x_even * sin + x_odd * cos], axis=-1)
    return out.reshape(n, d)


def scaled_causal_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Scaled dot-product scores with the causal mask applied (Eq. 1)."""
    n, d = q.shape
    p = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    return jnp.where(j <= i, p, NEG_INF)


def attention_probs(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Full causal softmax attention matrix A in [0,1]^{n x n} (Eq. 2)."""
    return jax.nn.softmax(scaled_causal_scores(q, k), axis=-1)


def dense_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Exact causal attention output O = A @ V (Eq. 3)."""
    return attention_probs(q, k) @ v


def row_lse(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Per-row logsumexp of the scaled causal scores; pass-1 oracle for the
    two-pass online aggregation kernel."""
    p = scaled_causal_scores(q, k)
    m = jnp.max(p, axis=-1)
    return m + jnp.log(jnp.sum(jnp.exp(p - m[:, None]), axis=-1))


def vs_aggregate(q: jnp.ndarray, k: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Ground-truth vertical/slash aggregation of the attention matrix.

    Returns ``(A_v, A_s)`` both of shape (n,), each summing to 1 (the paper
    normalizes the n-sum aggregates by n to form distributions) — Eq. 15.
    """
    a = attention_probs(q, k)
    n = a.shape[0]
    a_v = jnp.sum(a, axis=0) / n
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    off = (i - j).reshape(-1)
    a_s = (
        jnp.zeros((n,), dtype=a.dtype)
        .at[jnp.clip(off, 0, n - 1)]
        .add(jnp.where(off >= 0, a.reshape(-1), 0.0))
        / n
    )
    return a_v, a_s


def vs_mask(n: int, v_idx, s_idx) -> jnp.ndarray:
    """Boolean keep-mask (n, n) for Eq. 9 intersected with causality."""
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    keep_v = jnp.isin(j, jnp.asarray(np.asarray(v_idx), dtype=jnp.int32))
    keep_s = jnp.isin(i - j, jnp.asarray(np.asarray(s_idx), dtype=jnp.int32))
    return (keep_v | keep_s) & (j <= i)


def vs_sparse_attention(q, k, v, v_idx, s_idx) -> jnp.ndarray:
    """Reference sparse attention: softmax restricted to the VS mask (Eq. 4-5).

    The main diagonal (slash offset 0) is always kept so every causal row has
    finite softmax mass; the fused kernel makes the same guarantee.
    """
    n, _ = q.shape
    keep = vs_mask(n, v_idx, s_idx) | jnp.eye(n, dtype=bool)
    p = jnp.where(keep, scaled_causal_scores(q, k), NEG_INF)
    a = jax.nn.softmax(p, axis=-1)
    return a @ v


def attention_recall(q: jnp.ndarray, k: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Attention Recall R(S) (Eq. 6): retained causal attention mass / n."""
    a = attention_probs(q, k)
    n = a.shape[0]
    return jnp.sum(jnp.where(keep, a, 0.0)) / n
