"""Fused vertical-slash sparse attention Pallas kernel (§4.3 of the paper).

For each query row the admissible key set is ``I_v ∪ {i - s : s in I_s}``
(Eq. 9).  The kernel is gridded over query blocks; within a block it

  1. builds, per row, the merged candidate column list from the (sorted,
     padded) vertical index list and the slash offset list — the union is
     formed on the fly, never materialized as an ``n x n`` mask;
  2. gathers the candidate K/V rows on demand ("fetch key-value pairs on
     demand", §4.3);
  3. masks duplicates (a column selected by both a vertical index and a slash
     offset must be counted once), padding sentinels and non-causal cells;
  4. runs a numerically stable masked softmax over the ``k_v + k_s``
     candidates and accumulates the output.

Index lists are fixed-capacity (static shapes for AOT lowering): callers pad
``v_idx`` / ``s_idx`` with the sentinel ``n`` and pass the true lengths.
Slash offset 0 (the main diagonal) is implicitly guaranteed by callers that
need finite rows; the Rust budgeter always includes it, and ``ref.py``
mirrors the same convention.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the per-row gather
trades the paper's per-block Merge-Path union (a GPU warp algorithm) for a
VMEM-resident (block_q, k_v+k_s, d) gather that the MXU consumes as a batch
of skinny matmuls; the Rust hot path implements the actual Merge-Path
partitioned union where the block-union strategy pays off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _vs_sparse_kernel(
    q_ref, k_ref, v_ref, vidx_ref, sidx_ref, lens_ref, o_ref, *, n: int, scale: float
):
    """Grid: (num_q_blocks,)."""
    qi = pl.program_id(0)
    q = q_ref[...]  # (block_q, d)
    block_q = q.shape[0]
    rows = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # (bq,)

    v_idx = vidx_ref[...]  # (kv,) int32, padded with n
    s_idx = sidx_ref[...]  # (ks,) int32, padded with n
    v_len = lens_ref[0]
    s_len = lens_ref[1]
    kv = v_idx.shape[0]
    ks = s_idx.shape[0]

    v_valid = jax.lax.iota(jnp.int32, kv) < v_len  # (kv,)
    s_valid = jax.lax.iota(jnp.int32, ks) < s_len  # (ks,)

    # Per-row candidate columns: vertical cols broadcast, slash cols i - s.
    vcols = jnp.broadcast_to(v_idx[None, :], (block_q, kv))  # (bq, kv)
    scols = rows[:, None] - s_idx[None, :]  # (bq, ks)

    # Validity masks: in range, causal, unpadded.
    vmask = v_valid[None, :] & (vcols <= rows[:, None]) & (vcols < n)
    smask = s_valid[None, :] & (scols >= 0) & (scols <= rows[:, None])
    # Duplicate suppression: drop a slash candidate that also appears as a
    # valid vertical candidate for the same row.
    dup = jnp.any(
        (scols[:, :, None] == vcols[:, None, :]) & vmask[:, None, :], axis=-1
    )  # (bq, ks)
    smask = smask & ~dup

    cols = jnp.concatenate([vcols, scols], axis=1)  # (bq, m)
    mask = jnp.concatenate([vmask, smask], axis=1)  # (bq, m)
    cols_safe = jnp.clip(cols, 0, n - 1)

    # On-demand K/V gather: (bq, m, d).
    k_g = pl.load(k_ref, (slice(None), slice(None)))[cols_safe]
    v_g = pl.load(v_ref, (slice(None), slice(None)))[cols_safe]

    p = jnp.einsum("id,imd->im", q, k_g) * scale
    p = jnp.where(mask, p, NEG_INF)
    m_row = jnp.max(p, axis=-1, keepdims=True)
    # Guard fully-masked rows (can only happen for row 0 when callers omit
    # offset 0); exp(NEG_INF - NEG_INF) would be NaN otherwise.
    m_row = jnp.maximum(m_row, -0.5 * jnp.float32(NEG_INF) * 0 + (NEG_INF / 2))
    e = jnp.where(mask, jnp.exp(p - m_row), 0.0)
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    o_ref[...] = jnp.einsum("im,imd->id", e / denom, v_g)


def vs_sparse_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    v_idx: jnp.ndarray,
    s_idx: jnp.ndarray,
    lens: jnp.ndarray,
    *,
    block_q: int = 64,
) -> jnp.ndarray:
    """Fused sparse attention over a vertical-slash index pair.

    Args:
      q, k, v: (n, d) float32.
      v_idx:   (kv,) int32 vertical column indices, padded with ``n``.
      s_idx:   (ks,) int32 slash offsets, padded with ``n``.
      lens:    (2,)  int32 = [v_len, s_len] true lengths.
    Returns (n, d) attention output.
    """
    n, d = q.shape
    block_q = min(block_q, n)
    assert n % block_q == 0
    scale = 1.0 / (d**0.5)
    kernel = functools.partial(_vs_sparse_kernel, n=n, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((v_idx.shape[0],), lambda i: (0,)),
            pl.BlockSpec((s_idx.shape[0],), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(q, k, v, v_idx.astype(jnp.int32), s_idx.astype(jnp.int32), lens.astype(jnp.int32))
