"""VSIndexer — the paper's lightweight index-prediction module (§4.1) and its
distillation trainer (§4.2).

Architecture (Eqs. 11-14): X = concat(K_rope, V) in R^{n x 2d};
Z = silu(X W_U + b_U); vertical scores softmax(Z W_V + b_V) over positions;
slash scores softmax over *offsets*.  Slash alignment convention: the score
produced at position j is assigned to offset o = n-1-j (relative distance
from the final token), so the learned per-position feature "how much do later
queries attend at my relative distance" lands at the offset the mask
construction consumes.  The Rust forward (rust/src/indexer/) uses the same
convention, so the weights exported by ``aot.py`` transfer directly.

The trainer freezes everything except the indexer (the backbone is not even
differentiated through — inputs are detached by construction) and minimizes
Eq. 17: KL(pred ‖ target) for both directions.  MSE / MSLE / cosine losses
are implemented for the Table-4 ablation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import synth
from .kernels import ref

EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class IndexerConfig:
    head_dim: int = 32
    hidden: int = 64  # paper uses 1024 for d=128 heads; scaled to the toy size

    @property
    def in_dim(self) -> int:
        return 2 * self.head_dim


def init_indexer(rng: np.random.Generator, cfg: IndexerConfig) -> dict:
    s = (2.0 / cfg.in_dim) ** 0.5
    return dict(
        wu=jnp.asarray(rng.normal(size=(cfg.in_dim, cfg.hidden)) * s, jnp.float32),
        bu=jnp.zeros((cfg.hidden,), jnp.float32),
        wv=jnp.asarray(rng.normal(size=(cfg.hidden, 1)) * (1.0 / cfg.hidden**0.5), jnp.float32),
        bv=jnp.zeros((1,), jnp.float32),
        ws=jnp.asarray(rng.normal(size=(cfg.hidden, 1)) * (1.0 / cfg.hidden**0.5), jnp.float32),
        bs=jnp.zeros((1,), jnp.float32),
    )


def indexer_forward(p: dict, k_rope: jnp.ndarray, v: jnp.ndarray):
    """Predict (A_v_hat, A_s_hat), each (n,) summing to 1."""
    x = jnp.concatenate([k_rope, v], axis=-1)  # (n, 2d)
    z = jax.nn.silu(x @ p["wu"] + p["bu"])  # (n, h)
    av_logit = (z @ p["wv"] + p["bv"])[:, 0]  # (n,)
    as_logit_pos = (z @ p["ws"] + p["bs"])[:, 0]  # (n,) per-position
    av = jax.nn.softmax(av_logit)
    a_s = jax.nn.softmax(as_logit_pos[::-1])  # offset o <- position n-1-o
    return av, a_s


# ---------------------------------------------------------------------------
# Losses (Table 4 ablation).  All take predicted / target distributions (n,).
# ---------------------------------------------------------------------------

def loss_kl(pred: jnp.ndarray, tgt: jnp.ndarray) -> jnp.ndarray:
    """Eq. 17: D_KL(pred ‖ target)."""
    return jnp.sum(pred * (jnp.log(pred + EPS) - jnp.log(tgt + EPS)))


def loss_mse(pred: jnp.ndarray, tgt: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((pred - tgt) ** 2) * pred.shape[0]  # scaled to KL magnitude


def loss_msle(pred: jnp.ndarray, tgt: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((jnp.log1p(pred * pred.shape[0]) - jnp.log1p(tgt * tgt.shape[0])) ** 2)


def loss_cosine(pred: jnp.ndarray, tgt: jnp.ndarray) -> jnp.ndarray:
    num = jnp.sum(pred * tgt)
    den = jnp.sqrt(jnp.sum(pred * pred) * jnp.sum(tgt * tgt)) + EPS
    return 1.0 - num / den


LOSSES = dict(kl=loss_kl, mse=loss_mse, msle=loss_msle, cosine=loss_cosine)


# ---------------------------------------------------------------------------
# Distillation trainer.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainConfig:
    steps: int = 300
    batch: int = 4
    seq_len: int = 256
    lr: float = 3e-3
    warmup: int = 20
    loss: str = "kl"
    seed: int = 0
    synth_cfg: synth.SynthConfig = dataclasses.field(default_factory=synth.SynthConfig)


def make_batch(rng: np.random.Generator, tc: TrainConfig):
    """One batch of (K_rope, V, A_v, A_s) distillation tuples from the
    Appendix-A.1 generator; targets via the exact reference aggregation."""
    ks, vs, avs, ass_ = [], [], [], []
    for b in range(tc.batch):
        q, k, v, _ = synth.gen_qkv(rng, tc.seq_len, tc.synth_cfg, head_seed=int(rng.integers(8)))
        av, a_s = ref.vs_aggregate(jnp.asarray(q), jnp.asarray(k))
        ks.append(k)
        vs.append(v)
        avs.append(av)
        ass_.append(a_s)
    return (
        jnp.asarray(np.stack(ks)),
        jnp.asarray(np.stack(vs)),
        jnp.stack(avs),
        jnp.stack(ass_),
    )


def _lr_at(step: int, tc: TrainConfig) -> float:
    if step < tc.warmup:
        return tc.lr * (step + 1) / tc.warmup
    t = (step - tc.warmup) / max(tc.steps - tc.warmup, 1)
    return tc.lr * 0.5 * (1.0 + float(np.cos(np.pi * t)))


def distill(cfg: IndexerConfig, tc: TrainConfig, log_every: int = 0):
    """Train the VSIndexer by distillation; returns (params, history)."""
    rng = np.random.default_rng(tc.seed)
    params = init_indexer(rng, cfg)
    loss_fn = LOSSES[tc.loss]

    def batch_loss(p, kb, vb, avb, asb):
        def one(k, v, av_t, as_t):
            av_p, as_p = indexer_forward(p, k, v)
            return loss_fn(av_p, av_t) + loss_fn(as_p, as_t)

        return jnp.mean(jax.vmap(one)(kb, vb, avb, asb))

    grad_fn = jax.jit(jax.value_and_grad(batch_loss))

    # Adam state.
    m = jax.tree.map(jnp.zeros_like, params)
    s = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    history = []
    for step in range(tc.steps):
        kb, vb, avb, asb = make_batch(rng, tc)
        loss, g = grad_fn(params, kb, vb, avb, asb)
        lr = _lr_at(step, tc)
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        s = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, s, g)
        t = step + 1
        mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
        sh = jax.tree.map(lambda a: a / (1 - b2**t), s)
        params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mh, sh)
        history.append(float(loss))
        if log_every and step % log_every == 0:
            print(f"  distill step {step:4d} loss {float(loss):.4f} lr {lr:.2e}")
    return params, history


def recall_at_sparsity(
    params: dict,
    rng: np.random.Generator,
    sparsity: float,
    *,
    n: int = 256,
    trials: int = 8,
    scfg: synth.SynthConfig | None = None,
) -> float:
    """Attention recall (Eq. 6) of the predicted VS mask at a given sparsity.

    The (1-sparsity) * n^2/2 causal budget is split between vertical columns
    and slash offsets proportionally to their predicted mass.
    """
    scfg = scfg or synth.SynthConfig()
    total = 0.0
    for t in range(trials):
        q, k, v, _ = synth.gen_qkv(rng, n, scfg, head_seed=t % 8)
        av, a_s = indexer_forward(params, jnp.asarray(k), jnp.asarray(v))
        av, a_s = np.asarray(av), np.asarray(a_s)
        keep_cells = (1.0 - sparsity) * (n * (n + 1) / 2)
        mass_v, mass_s = float(av.sum()), float(a_s.sum())
        # Average causal cells covered: a vertical column ~n/2 cells, a slash
        # offset o covers n-o cells (~n/2 average).
        cols = max(1, int(keep_cells * mass_v / (mass_v + mass_s) / (n / 2)))
        offs = max(1, int(keep_cells * mass_s / (mass_v + mass_s) / (n / 2)))
        v_idx = np.argsort(-av)[:cols]
        s_idx = np.argsort(-a_s)[:offs]
        keep = ref.vs_mask(n, v_idx, s_idx)
        total += float(ref.attention_recall(jnp.asarray(q), jnp.asarray(k), keep))
    return total / trials
