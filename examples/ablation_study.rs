//! Ablation study driver: regenerates the paper's three ablations (Tables
//! 3-5) at reduced scale plus two design-choice ablations DESIGN.md calls
//! out: the budget-calibration exponents and the Merge-Path block union.
//!
//! Run: `cargo run --release --example ablation_study`

use vsprefill::attention::dense::attention_probs;
use vsprefill::baselines::{recall_of_spec, SparsePredictor};
use vsprefill::experiments::{table3, table4, table5};
use vsprefill::sparse_attn::VsPrefill;
use vsprefill::synth::{gen_head, SynthConfig};
use vsprefill::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== VSPrefill ablation study ==\n");

    println!("[1/5] sparsity strategies (Table 3, reduced scale)");
    let rows = table3::run(512, 4, 42);
    print!("{}", table3::render(&rows));

    println!("\n[2/5] loss functions (Table 4, reduced scale)");
    let rows = table4::run(150, 4, 42);
    print!("{}", table4::render(&rows));

    println!("\n[3/5] input features (Table 5, reduced scale)");
    let rows = table5::run(150, 4, 42);
    print!("{}", table5::render(&rows));

    println!("\n[4/5] budget-calibration exponents (design ablation)");
    let synth = SynthConfig::default();
    let ix = vsprefill::experiments::experiment_indexer(&synth);
    let mut rng = Rng::new(9);
    let head = gen_head(&mut rng, 1024, &synth, 1);
    let a = attention_probs(&head.q, &head.k);
    for (sv, ss) in [(1.0f32, 1.0f32), (0.5, 2.0), (2.0, 2.0), (0.5, 1.0)] {
        let vsp = VsPrefill { sharpen_v: sv, sharpen_s: ss, ..VsPrefill::new(ix.clone()) };
        let spec = vsp.predict(&head, 0.5);
        println!(
            "  gamma_v={sv:.1} gamma_s={ss:.1}: density {:.3} recall {:.3}",
            spec.density(1024),
            recall_of_spec(&a, &spec)
        );
    }

    println!("\n[5/5] Merge-Path union vs naive mask materialization");
    let idx = {
        let vsp = VsPrefill::new(ix);
        vsp.predict_kv(&head.k, &head.v, 0.5)
    };
    let n = 1024;
    let t0 = std::time::Instant::now();
    let mut total_cols = 0usize;
    for q0 in (0..n).step_by(64) {
        let cols = vsprefill::sparse::merge::block_columns(&idx.vertical, &idx.slash, q0, 64, n);
        total_cols += cols.len();
    }
    let merge_t = t0.elapsed();
    let t1 = std::time::Instant::now();
    let dense = vsprefill::sparse::mask::dense_mask(&idx, n);
    let naive_cols: usize = dense.iter().map(|r| r.iter().filter(|&&b| b).count()).sum();
    let naive_t = t1.elapsed();
    println!(
        "  merge-path: {total_cols} block-columns in {:?}; naive mask: {naive_cols} cells in {:?} ({}x slower)",
        merge_t,
        naive_t,
        (naive_t.as_nanos() / merge_t.as_nanos().max(1))
    );
    println!("\nOK");
    Ok(())
}
