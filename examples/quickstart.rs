//! Quickstart: the VSPrefill pipeline end to end on one synthetic context.
//!
//!   1. generate a long-context attention head (Appendix-A.1 model)
//!   2. predict vertical/slash importance with the VSIndexer
//!   3. pick budgets with the adaptive cumulative threshold (Eq. 18-19)
//!   4. execute fused vertical-slash sparse attention
//!   5. compare against exact attention: recall, density, max error
//!   6. serve one request through the real stack (`serve::EngineBuilder`)
//!
//! Run: `cargo run --release --example quickstart`

use vsprefill::attention::dense::attention_probs;
use vsprefill::attention::flash::flash_attention;
use vsprefill::attention::recall::recall_of_vs;
use vsprefill::indexer::train::{distill, TrainConfig};
use vsprefill::sparse_attn::exec::sparse_attention_vs;
use vsprefill::sparse_attn::VsPrefill;
use vsprefill::synth::{gen_head, SynthConfig};
use vsprefill::util::rng::Rng;

fn main() {
    let n = 1024;
    println!("== VSPrefill quickstart (n = {n}) ==\n");

    // 1. a context with vertical-slash structure
    let mut rng = Rng::new(7);
    let head = gen_head(&mut rng, n, &SynthConfig::default(), 2);
    println!("injected heavy-hitter columns: {:?}", head.heavy);

    // 2. distill a VSIndexer (the serving stack loads Python-distilled
    //    weights from artifacts/; here we train natively in-process)
    println!("distilling VSIndexer ...");
    let (ix, hist) = distill(&TrainConfig { steps: 200, ..Default::default() });
    println!("  loss {:.2} -> {:.3}", hist[0], hist.last().unwrap());

    // 3. adaptive selection
    let vsp = VsPrefill::new(ix);
    let idx = vsp.predict_kv(&head.k, &head.v, 0.5);
    println!(
        "selected {} vertical columns, {} slash offsets (density {:.1}%)",
        idx.vertical.len(),
        idx.slash.len(),
        100.0 * idx.density(n)
    );
    println!(
        "  top verticals: {:?}",
        &idx.vertical[..idx.vertical.len().min(8)]
    );
    println!("  top offsets:   {:?}", &idx.slash[..idx.slash.len().min(8)]);

    // 4. fused sparse attention vs 5. exact attention
    let sparse = sparse_attention_vs(&head.q, &head.k, &head.v, &idx, 64);
    let dense = flash_attention(&head.q, &head.k, &head.v, 64, 64);
    let a = attention_probs(&head.q, &head.k);
    let recall = recall_of_vs(&a, &idx);
    println!("\nattention recall (Eq. 6): {:.3}", recall);
    println!("sparse-vs-dense output max |err|: {:.4}", sparse.max_abs_diff(&dense));
    println!(
        "flops kept: {:.1}% of dense",
        100.0 * idx.covered_cells(n) as f64 / (n * (n + 1) / 2) as f64
    );
    assert!(recall > 0.8, "quickstart sanity: recall should be high");

    // 6. the same pipeline through the serving stack: every embedder-facing
    //    entry point is one EngineBuilder call away.
    println!("\nserving one request through EngineBuilder (native backend) ...");
    let coordinator = vsprefill::serve::EngineBuilder::new()
        .indexer(vsp.indexer.clone())
        .build()
        .expect("default config is valid");
    let mut req = vsprefill::coordinator::PrefillRequest::synthetic(
        1,
        n,
        7,
        vsprefill::coordinator::AttentionMode::Sparse,
    );
    req.max_new_tokens = 4;
    let resp = coordinator.prefill(req).expect("admission");
    assert!(resp.ok, "{:?}", resp.error);
    println!(
        "  served: bucket {}  density {:.3}  ttft {:.1}ms  tokens {:?}",
        resp.bucket,
        resp.density,
        resp.ttft_us as f64 / 1e3,
        resp.tokens
    );

    println!("\nOK — see examples/needle_serving.rs for the full serving stack.");
}
