//! Needle-retrieval serving demo: the full L3 stack (admission -> chunked
//! scheduler -> paged KV store -> engine) serving a mixed workload of dense
//! and sparse prefill requests over the TCP JSON-lines protocol, with a
//! needle-retrieval quality check per request budget.
//!
//! Uses the PJRT backend when `make artifacts` has run; falls back to the
//! native backend otherwise (`BackendKind::Auto` — the builder decides).
//!
//! Run: `cargo run --release --example needle_serving`

use std::sync::Arc;

use vsprefill::baselines::SparsePredictor;
use vsprefill::coordinator::{
    server::{Client, Server},
    CoordinatorConfig,
};
use vsprefill::evalsuite::{accuracy, task_head, ProbeCache, TaskInstance};
use vsprefill::serve::{BackendKind, EngineBuilder};
use vsprefill::sparse_attn::VsPrefill;
use vsprefill::synth::qwen_sim;

fn main() -> anyhow::Result<()> {
    let cfg = CoordinatorConfig { max_wait_ms: 2, ..Default::default() };
    // `Auto` picks the PJRT backend when compiled in and artifacts exist,
    // else the native backend — same builder call either way.
    let coordinator =
        Arc::new(EngineBuilder::new().config(cfg).backend(BackendKind::Auto).build()?);
    println!("== needle-retrieval serving demo ==\n");

    let server = Server::start(coordinator.clone(), 0)?;
    println!("serving on {}", server.addr);

    // Mixed closed-loop load from 3 clients.
    let addr = server.addr;
    let mut handles = Vec::new();
    for c in 0..3u64 {
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut client = Client::connect(addr)?;
            let mut lat = Vec::new();
            for i in 0..8u64 {
                let n = if i % 2 == 0 { 256 } else { 512 };
                let mode = if i % 4 == 0 { "dense" } else { "sparse" };
                let t0 = std::time::Instant::now();
                let resp = client.prefill_synthetic(c * 100 + i, n, c + i, mode, 0.5)?;
                anyhow::ensure!(resp.ok, "{:?}", resp.error);
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok(lat)
        }));
    }
    let mut lats = Vec::new();
    for h in handles {
        lats.extend(h.join().unwrap()?);
    }
    let s = vsprefill::util::stats::summarize(&lats);
    println!("\n24 requests served:");
    println!("  client-side latency p50 {:.1}ms p95 {:.1}ms", s.p50, s.p95);
    {
        let snap = coordinator.metrics.snapshot();
        println!(
            "  engine prefill p50 {:.0}us p95 {:.0}us | mean queue {:.0}us | mean density {:.3}",
            snap.p50_prefill_us, snap.p95_prefill_us, snap.mean_queue_us, snap.mean_density
        );
    }

    // Token generation over the same wire: request decode tokens and print
    // the streamed frames as they arrive ahead of the final response.
    println!("\ntoken generation (n = 256, 8 new tokens, sparse decode):");
    let mut gen_client = Client::connect(addr)?;
    let (frames, resp) = gen_client.generate(500, 256, 9, "sparse", 0.5, 8)?;
    anyhow::ensure!(resp.ok, "{:?}", resp.error);
    for f in &frames {
        println!("  frame {}: pos {}  token {}  itl {}us", f.index, f.pos, f.token, f.itl_us);
    }
    let tpot =
        resp.decode_us.iter().sum::<u64>() as f64 / resp.decode_us.len().max(1) as f64;
    println!(
        "  final: {} tokens | ttft {:.1}ms | mean tpot {:.0}us",
        resp.tokens.len(),
        resp.ttft_us as f64 / 1e3,
        tpot
    );
    let snap = coordinator.metrics.snapshot();
    println!(
        "  service itl p50 {:.0}us p95 {:.0}us | {} tokens generated",
        snap.p50_itl_us, snap.p95_itl_us, snap.tokens_generated
    );

    // Needle-retrieval quality at three budgets (offline check through the
    // same indexer family the engine uses).
    println!("\nneedle retrieval vs budget (n = 2048, 3 needles):");
    let synth = qwen_sim();
    let ix = vsprefill::experiments::experiment_indexer(&synth);
    let vsp = VsPrefill::new(ix);
    for budget in [0.2f32, 0.5, 0.8] {
        let inst = TaskInstance {
            task: "niah",
            n: 2048,
            critical: vec![400, 1000, 1500],
            probe_rows: 16,
            base_score: 100.0,
            difficulty: 1.0,
            seed: 3,
        };
        let head = task_head(&inst, &synth);
        let spec = vsp.predict(&head, budget);
        let probe = ProbeCache::new(&head, &inst);
        let r = probe.recall(&spec);
        println!(
            "  budget {budget:.1}: density {:.3}  critical recall {:.3}  est. task score {:.1}",
            spec.density(2048),
            r,
            accuracy::task_score(&inst, r)
        );
    }

    server.shutdown();
    println!("\nOK");
    Ok(())
}
