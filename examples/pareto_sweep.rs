//! Pareto sweep (Figure 5 companion): sweeps every method's budget knob at
//! one length and prints the accuracy/speedup frontier, marking the points
//! that are Pareto-optimal.
//!
//! Run: `cargo run --release --example pareto_sweep [--n 16384]`

use vsprefill::evalsuite::{evaluate_methods, ruler};
use vsprefill::experiments::MethodSet;
use vsprefill::sparse_attn::cost::CostModel;
use vsprefill::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["n", "reps"])?;
    let n = args.usize_or("n", 16384);
    let reps = args.usize_or("reps", 1);
    println!("== accuracy/speedup Pareto sweep @ n = {n} ==\n");

    let synth = vsprefill::synth::qwen_sim();
    let set = MethodSet::for_family(&synth, n);
    let methods = set.as_dyn();
    let names = ["FlashAttn", "StrLLM", "FlexPre", "SeerAttn", "VSPrefill"];
    let cost = CostModel::default_calibration();
    let instances = ruler::instances(n, reps, 42);

    let mut points: Vec<(String, f32, f64)> = Vec::new();
    for (mi, m) in methods.iter().enumerate() {
        let budgets: &[f32] = if mi == 0 { &[1.0] } else { &[0.15, 0.3, 0.5, 0.8] };
        for &b in budgets {
            let r = evaluate_methods(&[*m], &instances, &synth, b);
            let head = vsprefill::evalsuite::task_head(&instances[0], &synth);
            let spec = m.predict(&head, b);
            let c = cost.cost_of(&spec, *m, n, synth.head_dim);
            points.push((format!("{} @{b:.2}", names[mi]), r[0].0, c.speedup_vs_dense));
        }
    }

    // Pareto front: no other point with both higher score and speedup.
    let is_pareto = |i: usize| -> bool {
        !points.iter().enumerate().any(|(j, p)| {
            let dominates = p.1 >= points[i].1 && p.2 >= points[i].2;
            let strictly = p.1 > points[i].1 || p.2 > points[i].2;
            j != i && dominates && strictly
        })
    };
    println!("{:<20} {:>8} {:>9}  pareto", "config", "score", "speedup");
    for i in 0..points.len() {
        let (name, score, speedup) = &points[i];
        println!(
            "{:<20} {:>8.2} {:>8.2}x  {}",
            name,
            score,
            speedup,
            if is_pareto(i) { "*" } else { "" }
        );
    }
    Ok(())
}
